"""Block-paged KV pool: allocator, block tables, prefix sharing, COW.

This module is the HOST side of the paged KV-cache subsystem.  Device
storage (owned by the engine's cache pytree, built by
``network.init_paged_caches``) keeps every attention layer's K/V as a
single pool array

    (num_blocks, block_size, n_kv_heads, head_dim)

instead of the dense per-slot stripe ``(slots, max_len, ...)``.  A slot's
logical KV sequence is scattered across pool blocks; the mapping is the
slot's row of the **block table**

    tables : int32 (slots, blocks_per_slot),   blocks_per_slot = ceil(max_len / block_size)

where ``tables[s, j]`` is the pool block holding the slot's tokens at
logical positions ``[j*block_size, (j+1)*block_size)``.  The same table is
shared by every layer — each layer indexes its own pool array with the
same block ids.  Token position ``p`` of slot ``s`` therefore lives at
flat pool index ``tables[s, p // block_size] * block_size + p % block_size``,
which is exactly the gather the paged-decode kernel
(``kernels.paged_attention``) performs through scalar-prefetched tables.

Allocator invariants:

  * **Block 0 is the null/trash block.**  It is never handed out; table
    entries default to 0, and out-of-range writes (inactive slots whose
    ``pos`` keeps advancing in the batched decode step) land there.  Reads
    are always masked by the per-slot validity length, so trash contents
    are never observed.
  * **Ref counts.**  ``ref[b]`` counts the slots currently mapping block
    ``b`` plus one if the block is registered in the prefix cache.  A block
    returns to the free list only at ref == 0.
  * **Prefix sharing.**  Full prompt blocks are content-addressed by a
    chained hash (block tokens + parent hash, so a block's identity
    encodes its whole prefix).  Admission walks the prompt's full blocks
    through ``match_prefix``; every hit is mapped into the new slot's
    table (ref++) and its prefill is SKIPPED — the K/V bytes are already
    in the pool and RoPE is absolute-positional, so they are bit-identical
    to what a fresh prefill would write.
  * **Copy-on-write.**  Writes may only touch blocks with ref == 1.
    ``ensure_writable`` forks a shared block: a fresh block is allocated,
    the table entry is swapped, and the (src, dst) pair is appended to
    ``pending_copies`` for the engine to execute on-device.  (With
    full-block-only sharing the engine never appends into a shared block
    — shared prefixes are block-aligned and writes start at the prompt
    tail — but the pool enforces the invariant regardless, so any future
    partial-block sharing policy inherits a safe write path.)
  * **Lazy growth + rollback.**  ``extend`` grows a slot's table on
    demand (the speculative-decoding engine reserves one verify step
    ahead instead of the whole decode budget); ``truncate`` is the KV
    rollback — it drops the slot's mapping beyond the accepted tokens,
    freeing exclusively-owned tail blocks, unpinning (never freeing)
    blocks another slot or the prefix cache still references, and
    scrubbing pending COW copies into released blocks.
  * **Eviction.**  Finished slots release their refs but registered
    prefix blocks stay cached (the map's ref pins them).  When a
    reservation cannot be met, least-recently-used cached blocks with no
    other users are evicted until it can; if that still falls short the
    reservation returns None and the engine backs off (the request stays
    queued — never a crash).

**Dense fallback switch.**  ``ContinuousEngine(paged=False)`` bypasses
this module entirely and serves from the PR-1 dense stripes; the paged
engine is the default.  The two paths produce token-identical greedy
output (tested), differing only in storage layout and admission
scheduling — which is what makes the paged path a drop-in replacement.
"""

from __future__ import annotations

import collections
import dataclasses
from collections.abc import Sequence

import numpy as np

from repro.obs.metrics import NULL_METRIC, MetricsRegistry

#: the reserved null/trash block id (see module docstring)
NULL_BLOCK = 0


class PoolAuditError(AssertionError):
    """A pool invariant audit failed.

    Carries a machine-readable ``report`` — the full serialized pool
    state (:meth:`KVPool.snapshot_state`), the violated invariants, and
    the operation in flight — in the same shape the static model checker
    (``analysis.pool_model``) emits for counterexample traces, so a
    runtime ``audit=True`` failure is directly replayable offline.
    """

    def __init__(self, violations: Sequence[str], pool_state: dict,
                 pending_op: dict | None = None):
        self.violations = list(violations)
        self.report = {"violations": self.violations,
                       "pool": pool_state,
                       "pending_op": pending_op}
        lines = "\n  ".join(self.violations)
        op = f"\nduring op: {pending_op!r}" if pending_op else ""
        super().__init__(
            f"KV pool audit failed ({len(self.violations)} violation(s)):"
            f"\n  {lines}{op}\nreproducer: {self.report!r}")


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks covering ``n_tokens`` positions."""
    return -(-max(0, int(n_tokens)) // block_size)


def _hash_to_json(h: tuple) -> list:
    """Chained prefix hash (nested tuples of ints) -> JSON-safe lists."""
    return [_hash_to_json(x) if isinstance(x, tuple) else int(x)
            for x in h]


def _hash_from_json(v: list) -> tuple:
    """Inverse of :func:`_hash_to_json` (lists back to nested tuples)."""
    return tuple(_hash_from_json(x) if isinstance(x, list) else int(x)
                 for x in v)


@dataclasses.dataclass
class AdmitPlan:
    """Result of a successful admission reservation."""

    slot: int
    shared_tokens: int          # prefix length already resident (block-aligned)
    shared_blocks: tuple[int, ...]
    new_blocks: tuple[int, ...]

    @property
    def blocks(self) -> tuple[int, ...]:
        return self.shared_blocks + self.new_blocks


@dataclasses.dataclass(frozen=True)
class ProbeReport:
    """Non-mutating answer to "would this reservation fit right now?".

    Produced by :meth:`KVPool.probe` for the scheduling policies
    (``serving.policy``): ``shared`` blocks come free via the prefix
    cache, ``need_new`` must be allocated, and ``fits_now`` mirrors the
    exact arithmetic ``admit`` would apply (free list plus the cached
    blocks ``reserve`` may evict, EXCLUDING blocks the prefix match
    itself would pin) — a True probe means an immediately following
    ``admit`` succeeds, barring interleaved pool mutation.
    """

    total: int                  # blocks the full reservation spans
    shared: int                 # covered by cached prefix blocks
    need_new: int               # fresh blocks a reservation must allocate
    free: int                   # free-list size at probe time
    evictable: int              # cached blocks reserve() could evict

    @property
    def fits_now(self) -> bool:
        return self.need_new <= self.free + self.evictable


class KVPool:
    """Host-side bookkeeping for the paged KV cache (see module docstring).

    The pool never touches device memory; it hands the engine block ids,
    table rows and pending (src, dst) copy pairs, and the engine mirrors
    them into the device cache tree.
    """

    # registry mirrors (class-level no-op defaults: pools constructed
    # outside a telemetry scope — and ``pool_model``'s ``__init__``-
    # bypassing clones — record nowhere)
    _m_shared = _m_cow = _m_evict = _m_backoff = NULL_METRIC
    _m_peak = _m_used = NULL_METRIC

    def __init__(self, num_blocks: int, block_size: int, *, slots: int,
                 max_len: int, share_prefixes: bool = True,
                 quantized: bool = False,
                 metrics: "MetricsRegistry | None" = None):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        if block_size < 1:
            raise ValueError("block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.slots = slots
        self.max_len = max_len
        self.blocks_per_slot = blocks_for(max_len, block_size)
        self.share_prefixes = share_prefixes
        #: quantized block mode (cfg.quant_kv): device blocks are int8
        #: with per-position scale sidecars; ``scale_written`` tracks
        #: which blocks own live dequant state — mapped/cached/pending
        #: blocks must, freed blocks must NOT (a freed block keeping its
        #: flag would let a re-allocation dequant a previous owner's
        #: scales before its first write; the audit screens both ways)
        self.quantized = quantized
        self.scale_written = np.zeros(num_blocks, bool)

        # block 0 reserved: never allocated, never freed.
        self._free: "collections.deque[int]" = collections.deque(
            range(1, num_blocks))
        self.ref = np.zeros(num_blocks, np.int32)
        self.ref[NULL_BLOCK] = 1                       # pinned forever

        #: per-slot block tables (NULL_BLOCK-padded) + valid-entry counts
        self.tables = np.full((slots, self.blocks_per_slot), NULL_BLOCK,
                              np.int32)
        self.n_slot_blocks = np.zeros(slots, np.int32)

        # prefix cache: chained hash -> block id, LRU-ordered for eviction
        self._prefix: collections.OrderedDict[tuple, int] = (
            collections.OrderedDict())
        self._hash_of: dict[int, tuple] = {}           # reverse map

        #: (src, dst) copies the engine must apply on-device (COW forks)
        self.pending_copies: list[tuple[int, int]] = []

        # telemetry: the plain ints stay authoritative (tests and
        # ``stats()`` read them; ``pool_model`` clones copy them); a
        # bound MetricsRegistry receives mirrored ``kv_pool.*`` counts
        self.peak_used = 0
        self.shared_token_hits = 0
        self.cow_forks = 0
        self.evictions = 0
        self.backoffs = 0
        if metrics is not None:
            self.bind_metrics(metrics)

    def bind_metrics(self, metrics: "MetricsRegistry") -> None:
        """Mirror pool telemetry into ``kv_pool.*`` registry metrics
        (counts events AFTER binding; the ints are the lifetime truth)."""
        self._m_shared = metrics.counter(
            "kv_pool.shared_token_hits",
            "prompt tokens skip-prefilled via the prefix cache")
        self._m_cow = metrics.counter(
            "kv_pool.cow_forks", "copy-on-write block forks")
        self._m_evict = metrics.counter(
            "kv_pool.evictions", "cached prefix blocks evicted")
        self._m_backoff = metrics.counter(
            "kv_pool.backoffs", "reservations denied (pool exhausted)")
        self._m_peak = metrics.gauge(
            "kv_pool.peak_used_blocks", "high-watermark of used blocks")
        self._m_used = metrics.gauge(
            "kv_pool.used_blocks", "blocks currently in use")

    # -- accounting ----------------------------------------------------------

    @property
    def used_blocks(self) -> int:
        """Blocks currently out of the free list (excluding the null block)."""
        return self.num_blocks - 1 - len(self._free)

    def _note_usage(self) -> None:
        self.peak_used = max(self.peak_used, self.used_blocks)
        self._m_peak.set(self.peak_used)
        self._m_used.set(self.used_blocks)

    # -- raw allocation ------------------------------------------------------

    def _alloc_one(self) -> int | None:
        if not self._free:
            return None
        bid = self._free.popleft()
        assert self.ref[bid] == 0, (bid, self.ref[bid])
        self.ref[bid] = 1
        self._note_usage()
        return bid

    def _release_one(self, bid: int) -> None:
        if bid == NULL_BLOCK:
            return
        assert self.ref[bid] > 0, bid
        self.ref[bid] -= 1
        if self.ref[bid] == 0:
            # a block can only hit zero if the prefix map no longer pins it
            assert bid not in self._hash_of, bid
            # the dequant sidecar dies with the last ref: a freed block
            # must re-enter circulation scale-clean (audit invariant)
            self.scale_written[bid] = False
            self._free.append(bid)

    def _mark_written(self, bids) -> None:
        """Record live scale sidecars for mapped blocks (quantized mode);
        a no-op for fp pools so the flag array stays all-False."""
        if not self.quantized:
            return
        for bid in bids:
            if bid != NULL_BLOCK:
                self.scale_written[int(bid)] = True

    def _evict_cached(self, need: int) -> None:
        """Unregister LRU prefix blocks nobody else maps until ``need``
        free blocks are available (or the cache is exhausted)."""
        if need <= len(self._free):
            return
        for h in list(self._prefix):
            bid = self._prefix[h]
            if self.ref[bid] == 1:          # only the map holds it
                del self._prefix[h]
                del self._hash_of[bid]
                self._release_one(bid)
                self.evictions += 1
                self._m_evict.inc()
                if len(self._free) >= need:
                    return

    def reserve(self, n: int) -> list[int] | None:
        """Allocate ``n`` blocks atomically (evicting cached prefix blocks
        if needed); None (and a recorded backoff) when the pool cannot
        satisfy the reservation — the caller must retry later."""
        self._evict_cached(n)
        if len(self._free) < n:
            self.backoffs += 1
            self._m_backoff.inc()
            return None
        out = []
        for _ in range(n):
            out.append(self._alloc_one())
        return out

    # -- prefix sharing ------------------------------------------------------

    @staticmethod
    def _chain_hashes(tokens: Sequence[int], block_size: int,
                      n_blocks: int) -> list[tuple]:
        """Chained content keys, one per full block: block j's key is
        (parent key, block-j tokens) — the FULL chain, not a collapsed
        hash(), so two different prefixes can never alias a block (a
        64-bit hash collision here would silently serve another prompt's
        KV).  Dict lookups still hash the tuple internally; equality
        checks make collisions harmless."""
        hs: list[tuple] = []
        h: tuple = ()
        toks = [int(t) for t in tokens[:n_blocks * block_size]]
        for j in range(n_blocks):
            h = (h, tuple(toks[j * block_size:(j + 1) * block_size]))
            hs.append(h)
        return hs

    def match_prefix(self, prompt: Sequence[int]) -> list[int]:
        """Longest run of cached full prompt blocks; each returned block
        gets a ref for the caller.  Sharing only ever covers FULL blocks,
        so the shared length is always block-aligned and strictly shorter
        than the prompt (the last token is never shared: its logits seed
        decode, so at least the tail must be prefilled)."""
        if not self.share_prefixes:
            return []
        nfull = (len(prompt) - 1) // self.block_size   # keep >= 1 tail token
        out: list[int] = []
        for h in self._chain_hashes(prompt, self.block_size, nfull):
            bid = self._prefix.get(h)
            if bid is None:
                break
            self._prefix.move_to_end(h)                # LRU touch
            self.ref[bid] += 1
            out.append(bid)
        return out

    def register_prefix(self, prompt: Sequence[int],
                        blocks: Sequence[int]) -> None:
        """Content-address the prompt's full blocks so future admissions
        can reuse them.  Registering an already-cached hash is a no-op;
        a newly registered block gains the map's pinning ref."""
        if not self.share_prefixes:
            return
        nfull = min((len(prompt) - 1) // self.block_size, len(blocks))
        for j, h in enumerate(self._chain_hashes(prompt, self.block_size,
                                                 nfull)):
            bid = int(blocks[j])
            if h in self._prefix or bid in self._hash_of:
                continue
            self._prefix[h] = bid
            self._hash_of[bid] = h
            self.ref[bid] += 1

    # -- reservation probing / reclaim accounting ----------------------------

    def evictable_cached(self) -> int:
        """Cached prefix blocks ``reserve`` could evict right now (the
        map's pin is their only ref).  O(cached blocks) — callers probing
        a whole queue compute this once and pass it as ``probe``'s
        ``evictable_hint``."""
        return sum(1 for bid in self._hash_of if self.ref[bid] == 1)

    def probe(self, prompt: Sequence[int], max_new_tokens: int,
              evictable_hint: int | None = None) -> ProbeReport:
        """Answer "would ``admit(prompt, max_new_tokens)`` succeed right
        now?" WITHOUT mutating anything: no refs taken, no LRU touch, no
        backoff recorded.  Scheduling policies call this once per queued
        request per step (with ``evictable_hint`` =
        :meth:`evictable_cached` computed once for the batch), so it must
        stay side-effect free."""
        plen = len(prompt)
        total = min(blocks_for(plen + max_new_tokens, self.block_size),
                    self.blocks_per_slot)
        matched: list[int] = []
        if self.share_prefixes and plen > 0:
            nfull = (plen - 1) // self.block_size
            for h in self._chain_hashes(prompt, self.block_size, nfull):
                bid = self._prefix.get(h)
                if bid is None:
                    break
                matched.append(bid)
        matched = matched[:total]
        # evictable = cached blocks reserve() may reclaim (ref == 1, the
        # map's pin is the only user) MINUS the matched ones: admit()
        # pins those via match_prefix before reserving, so they are not
        # up for eviction in the very reservation being probed.
        if evictable_hint is None:
            evictable_hint = self.evictable_cached()
        evictable = evictable_hint - sum(1 for bid in matched
                                         if self.ref[bid] == 1)
        return ProbeReport(total=total, shared=len(matched),
                           need_new=total - len(matched),
                           free=len(self._free), evictable=evictable)

    def reclaimable_blocks(self, slot: int) -> int:
        """Blocks that return to the free list outright if the slot is
        released: exclusively-owned entries (ref == 1).  Blocks shared
        with another slot or pinned by the prefix cache (ref >= 2) stay
        with their other owners — eviction never frees referenced
        blocks.  (A preempt-release that REGISTERS the slot's prompt
        turns its full prompt blocks into cached-evictable rather than
        free, which ``reserve`` can still reclaim under pressure.)"""
        n = int(self.n_slot_blocks[slot])
        return sum(1 for b in self.tables[slot, :n]
                   if self.ref[int(b)] == 1)

    # -- admission / release -------------------------------------------------

    def admit(self, slot: int, prompt: Sequence[int],
              max_new_tokens: int) -> AdmitPlan | None:
        """Reserve everything request ``(prompt, max_new_tokens)`` can ever
        touch in slot ``slot``: shared prefix blocks are mapped in, the
        rest is allocated up front so decode can never fail mid-flight.
        Returns None (clean backoff) if the pool is too full right now."""
        assert self.n_slot_blocks[slot] == 0, f"slot {slot} not released"
        plen = len(prompt)
        total = min(blocks_for(plen + max_new_tokens, self.block_size),
                    self.blocks_per_slot)
        shared = self.match_prefix(prompt)
        if len(shared) > total:     # degenerate: tiny decode budget
            for bid in shared[total:]:
                self._release_one(bid)
            shared = shared[:total]
        fresh = self.reserve(total - len(shared))
        if fresh is None:
            for bid in shared:
                self._release_one(bid)
            return None
        row = list(shared) + fresh
        self.tables[slot, :len(row)] = row
        self.tables[slot, len(row):] = NULL_BLOCK
        self.n_slot_blocks[slot] = len(row)
        self._mark_written(row)
        # count reuse only for admissions that actually land: a backoff
        # releases the matched refs and retries, and must not double-count
        self.shared_token_hits += len(shared) * self.block_size
        self._m_shared.inc(len(shared) * self.block_size)
        self._note_usage()
        return AdmitPlan(slot=slot,
                         shared_tokens=len(shared) * self.block_size,
                         shared_blocks=tuple(shared),
                         new_blocks=tuple(fresh))

    def extend(self, slot: int, total_tokens: int) -> bool:
        """Grow the slot's table to cover ``total_tokens`` logical
        positions (allocating fresh blocks, evicting cached prefix blocks
        under pressure).  The speculative-decoding engine reserves its
        decode span LAZILY — one verify step ahead — instead of the whole
        ``max_new`` budget up front, so rejected speculation can actually
        return blocks to the pool (:meth:`truncate`).  Returns False
        (clean backoff, counted) when the pool cannot grow the table; the
        caller degrades (shorter speculation, or preempt-and-requeue)."""
        need = min(blocks_for(total_tokens, self.block_size),
                   self.blocks_per_slot)
        cur = int(self.n_slot_blocks[slot])
        if need <= cur:
            return True
        fresh = self.reserve(need - cur)
        if fresh is None:
            return False
        self.tables[slot, cur:need] = fresh
        self.n_slot_blocks[slot] = need
        self._mark_written(fresh)
        self._note_usage()
        return True

    def truncate(self, slot: int, n_keep: int) -> int:
        """KV rollback: shrink the slot's mapping to the first
        ``blocks_for(n_keep)`` blocks (the blocks still holding accepted
        tokens) and release the tail — the blocks a rejected speculation
        wrote garbage into.  Returns the number of table entries dropped.

        Ref semantics mirror :meth:`release_slot`: a tail block another
        slot still maps, or the prefix cache still pins, only loses THIS
        slot's ref (unpinned, never freed); an exclusively-owned tail
        block returns to the free list.  Pending copy-on-write forks whose
        destination lies in the released tail are scrubbed — the fork
        never materializes on device, so a freed destination block can be
        re-allocated immediately without a stale copy racing it.
        ``check()`` holds afterwards by construction."""
        keep = min(blocks_for(max(0, int(n_keep)), self.block_size),
                   self.blocks_per_slot)
        cur = int(self.n_slot_blocks[slot])
        if keep >= cur:
            return 0
        dropped = [int(b) for b in self.tables[slot, keep:cur]]
        self._scrub_pending(set(dropped))
        for bid in dropped:
            self._release_one(bid)
        self.tables[slot, keep:cur] = NULL_BLOCK
        self.n_slot_blocks[slot] = keep
        return cur - keep

    def release_slot(self, slot: int, *, prompt: Sequence[int] | None
                     = None) -> None:
        """Drop the slot's refs.  With ``prompt`` given, its full blocks are
        first registered in the prefix cache (so they survive the release
        and a later identical prompt re-admits them — free/re-admit
        cycles keep ref counts exact, tested)."""
        n = int(self.n_slot_blocks[slot])
        row = [int(b) for b in self.tables[slot, :n]]
        if prompt is not None:
            self.register_prefix(prompt, row)
        # pending COW copies into the released row die with it (same
        # hazard truncate scrubs: a freed destination must never be
        # re-allocated with a stale device copy still queued against it)
        self._scrub_pending(set(row))
        for bid in row:
            self._release_one(bid)
        self.tables[slot, :] = NULL_BLOCK
        self.n_slot_blocks[slot] = 0

    # -- copy-on-write -------------------------------------------------------

    def ensure_writable(self, slot: int, first_pos: int, last_pos: int
                        ) -> None:
        """Fork any shared block the write span [first_pos, last_pos]
        touches (COW).  Device copies are queued on ``pending_copies`` for
        the engine to apply BEFORE the write executes.

        The slot's ref on the forked source is NOT dropped here — it
        transfers to the pending-copy entry and is released by
        :meth:`take_copies` once the engine owns the device copy.  An
        unpinned pending source could be freed and re-allocated (via a
        concurrent release/evict) before the copy executes, so the copy
        would read another request's KV bytes.  The bounded model checker
        (``analysis.pool_model``) finds that race in four ops against the
        eager-release variant; ``BuggyPoolEagerCOWRelease`` keeps it as a
        seeded mutant."""
        j0 = first_pos // self.block_size
        j1 = min(last_pos // self.block_size, self.blocks_per_slot - 1)
        for j in range(j0, j1 + 1):
            bid = int(self.tables[slot, j])
            if bid == NULL_BLOCK or self.ref[bid] <= 1:
                continue
            fresh = self._alloc_one()
            if fresh is None:
                # admission reserved the slot's whole span, so a fork can
                # only fail if sharing outran the reservation — evict and
                # retry once; a genuine exhaustion here is a bug upstream.
                self._evict_cached(1)
                fresh = self._alloc_one()
                if fresh is None:
                    raise MemoryError("KV pool exhausted during COW fork")
            # the slot's ref on ``bid`` now backs the pending entry
            self.pending_copies.append((bid, fresh))
            self.cow_forks += 1
            self._m_cow.inc()
            if self.quantized:
                # the queued device copy moves payload AND sidecar, so
                # the fork destination inherits the source's dequant
                # state the moment the pair is queued
                self.scale_written[fresh] = self.scale_written[bid]
            self.tables[slot, j] = fresh

    def take_copies(self) -> list[tuple[int, int]]:
        """Pop the queued (src, dst) COW copies for on-device execution,
        releasing each source's pending pin (the engine holds the bytes
        from here on)."""
        out, self.pending_copies = self.pending_copies, []
        for src, _dst in out:
            self._release_one(src)
        return out

    def _scrub_pending(self, dropped: "set[int]") -> None:
        """Drop queued COW copies whose destination is being released and
        release their sources' pending pins — the fork never materializes
        on device, so neither side of the pair may stay pinned by it."""
        if not self.pending_copies:
            return
        keep: list[tuple[int, int]] = []
        for src, dst in self.pending_copies:
            if dst in dropped:
                self._release_one(src)
            else:
                keep.append((src, dst))
        self.pending_copies = keep

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict[str, int]:
        return {"num_blocks": self.num_blocks - 1,
                "block_size": self.block_size,
                "quantized": int(self.quantized),
                "used": self.used_blocks,
                "peak_used": self.peak_used,
                "cached_prefix_blocks": len(self._prefix),
                "shared_token_hits": self.shared_token_hits,
                "cow_forks": self.cow_forks,
                "evictions": self.evictions,
                "backoffs": self.backoffs}

    def snapshot_state(self) -> dict:
        """JSON-serializable dump of the complete pool state — the
        ``pool`` field of :class:`PoolAuditError` reproducers, of
        model-checker counterexamples, and of engine warm-restart
        snapshots.  ``prefix`` preserves the cache's LRU order (front =
        coldest) so :meth:`from_snapshot` rebuilds eviction behavior
        exactly; ``prefix_blocks`` stays for older reproducer readers."""
        return {
            "num_blocks": int(self.num_blocks),
            "block_size": int(self.block_size),
            "slots": int(self.slots),
            "max_len": int(self.max_len),
            "share_prefixes": bool(self.share_prefixes),
            "quantized": bool(self.quantized),
            "scale_written": [int(b) for b
                              in np.flatnonzero(self.scale_written)],
            "free": [int(b) for b in self._free],
            "ref": [int(r) for r in self.ref],
            "tables": self.tables.tolist(),
            "n_slot_blocks": [int(n) for n in self.n_slot_blocks],
            "prefix_blocks": sorted(int(b) for b in self._hash_of),
            "prefix": [[_hash_to_json(h), int(b)]
                       for h, b in self._prefix.items()],
            "pending_copies": [[int(s), int(d)]
                               for s, d in self.pending_copies],
        }

    @classmethod
    def from_snapshot(cls, state: dict) -> "KVPool":
        """Rebuild a pool from :meth:`snapshot_state` output: identical
        behavioral state (allocator order, refcounts, tables, prefix
        cache in LRU order, pending COW copies); telemetry counters
        restart at zero.  Round-trip identity is a model-checker
        invariant (``analysis.pool_model``) and the offline half of the
        warm-restart path (docs/RELIABILITY.md)."""
        pool = cls(int(state["num_blocks"]), int(state["block_size"]),
                   slots=int(state["slots"]), max_len=int(state["max_len"]),
                   share_prefixes=bool(state.get("share_prefixes", True)),
                   quantized=bool(state.get("quantized", False)))
        for bid in state.get("scale_written", []):
            pool.scale_written[int(bid)] = True
        pool._free = collections.deque(int(b) for b in state["free"])
        pool.ref = np.asarray(state["ref"], np.int32)
        pool.tables = np.asarray(state["tables"], np.int32)
        pool.n_slot_blocks = np.asarray(state["n_slot_blocks"], np.int32)
        pool._prefix = collections.OrderedDict(
            (_hash_from_json(h), int(b))
            for h, b in state.get("prefix", []))
        pool._hash_of = {b: h for h, b in pool._prefix.items()}
        pool.pending_copies = [(int(s), int(d))
                               for s, d in state["pending_copies"]]
        return pool

    def audit_violations(self) -> list[str]:
        """Every broken invariant, as human-readable strings; empty when
        the pool is consistent.  Non-raising — both the runtime audit
        (:meth:`check`) and the bounded model checker
        (``analysis.pool_model``) judge states through this one
        predicate, so they can never disagree on what counts as a bug.

        Invariants: (1) ref conservation — ``ref[b]`` equals the slot
        table mappings of ``b`` plus its prefix-map pin, its pending-COW
        source pins, and the null block's permanent pin; (2) the free
        list holds exactly the ref==0 blocks, each once (a duplicate is a
        double free, a ref>0 entry is a use-after-free window, a missing
        ref==0 block is a leak); (3) pending copies reference live
        blocks with a mapped, exclusively-owned destination."""
        out: list[str] = []
        counts = np.zeros(self.num_blocks, np.int64)
        counts[NULL_BLOCK] += 1
        for s in range(self.slots):
            for b in self.tables[s, :self.n_slot_blocks[s]]:
                counts[int(b)] += 1
        for bid in self._hash_of:
            counts[bid] += 1
        for src, _dst in self.pending_copies:
            counts[int(src)] += 1          # pending pin until take_copies
        free_list = [int(b) for b in self._free]
        free = set(free_list)
        if len(free) != len(free_list):
            dupes = sorted(b for b in free
                           if free_list.count(b) > 1)
            out.append(f"double free: blocks {dupes} appear more than "
                       f"once on the free list")
        if NULL_BLOCK in free:
            out.append("null block on the free list")
        for bid in range(self.num_blocks):
            c, r = int(counts[bid]), int(self.ref[bid])
            if c != r:
                kind = "leak (ref outlives users)" if r > c else \
                    "dangling use (users outnumber ref)"
            else:
                kind = None
            if kind:
                out.append(f"refcount: block {bid} has {c} user(s) but "
                           f"ref {r} — {kind}")
            if r > 0 and bid in free:
                out.append(f"block {bid} on the free list with ref {r} "
                           f"(use-after-free window)")
            if r == 0 and bid not in free:
                out.append(f"block {bid} has ref 0 but is not on the "
                           f"free list (leaked)")
            if r == 0 and bid in self._hash_of:
                out.append(f"prefix cache maps freed block {bid}")
        for src, dst in self.pending_copies:
            if self.ref[int(src)] <= 0:
                out.append(f"pending COW copy reads freed source block "
                           f"{int(src)}")
            if self.ref[int(dst)] <= 0:
                out.append(f"pending COW copy writes freed destination "
                           f"block {int(dst)}")
        if self.quantized:
            # scale-sidecar invariant (quantized block mode): live blocks
            # own live dequant state, freed blocks own none.  A stale
            # flag on a freed block is the quantized use-after-free — a
            # re-allocation could dequant a previous owner's scales.
            if self.scale_written[NULL_BLOCK]:
                out.append("null block marked scale-written")
            for bid in range(1, self.num_blocks):
                r, w = int(self.ref[bid]), bool(self.scale_written[bid])
                if r == 0 and w:
                    out.append(f"stale scale sidecar: freed block {bid} "
                               f"still marked written")
                if r > 0 and int(counts[bid]) > 0 and not w:
                    out.append(f"block {bid} is live with no scale "
                               f"sidecar recorded — dequant state lost")
        return out

    def check(self, pending_op: dict | None = None) -> None:
        """Internal-consistency audit (``audit=True`` engines, tests):
        raises :class:`PoolAuditError` with a serialized reproducer —
        full pool state plus the operation in flight — when any
        :meth:`audit_violations` invariant is broken."""
        violations = self.audit_violations()
        if violations:
            raise PoolAuditError(violations, self.snapshot_state(),
                                 pending_op)
