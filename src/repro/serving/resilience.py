"""Serving fault-tolerance plane: deterministic fault injection,
lifecycle-guard configuration, error classification, and the
warm-restart driver (docs/RELIABILITY.md).

The design splits into four pieces that the engine composes:

* :class:`FaultPlane` — a seeded, schedule-driven injector with seams
  at KV-pool allocation (``reserve``/``extend`` denials), jitted
  dispatch (raise at engine step N), draft providers (garbage drafts),
  request payloads (poison: a rid whose dispatch raises), and process
  crashes (:class:`EngineCrash`, the warm-restart drill).  Schedules
  are plain dicts (:meth:`FaultPlane.to_schedule` /
  :meth:`FaultPlane.from_schedule`) replayable the same way
  ``analysis.pool_model`` replays counterexamples; the firing machinery
  is the training stack's ``runtime.faults.FailureInjector``, not a
  duplicate.
* :class:`ResilienceConfig` — the engine's lifecycle-guard knobs:
  load-shedding bound, bounded admission retry with exponential
  backoff, dispatch-retry budget, adaptive ``spec_k`` degradation.
  Every default is the legacy behavior, so a default-constructed config
  (what ``resilience=None`` gives you) is a no-op.
* :func:`classify_error` — the ``Result.error`` taxonomy.
* :func:`serve_with_restarts` — drives an engine through crash faults:
  on :class:`EngineCrash` it snapshots the dying engine
  (``ContinuousEngine.snapshot``), builds a fresh one, and re-admits
  every in-flight request through the prefix-cache skip-prefill path;
  greedy outputs are token-identical to an uncrashed run
  (gated in ``tests/test_chaos.py`` and serve_bench's ``paged_chaos``
  row).  The loop itself is ``runtime.faults.run_with_restarts``.

Everything here is host-side and import-light: no jax, no engine
import (the engine imports *us*).
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable, Sequence
from typing import Any

import numpy as np

from repro.runtime.faults import (FailureInjector, RestartPolicy,
                                  run_with_restarts)

#: the injectable fault kinds (schedule `kind` field)
FAULT_KINDS = ("reserve", "extend", "dispatch", "draft", "poison", "crash")

#: the Result.status vocabulary — every submitted request terminates
#: with exactly one of these (docs/RELIABILITY.md)
RESULT_STATUSES = ("ok", "cancelled", "timeout", "shed", "failed")


class InjectedFault(RuntimeError):
    """A fault the plane injected on purpose.  ``rid >= 0`` marks a
    poison fault targeting one request (the engine quarantines just that
    request); ``rid == -1`` is an untargeted transient (the engine
    retries the whole dispatch)."""

    def __init__(self, kind: str, *, rid: int = -1, step: int = -1):
        super().__init__(f"[injected] {kind} fault"
                         + (f" targeting rid {rid}" if rid >= 0 else "")
                         + (f" at step {step}" if step >= 0 else ""))
        self.kind = kind
        self.rid = rid
        self.step = step


class EngineCrash(RuntimeError):
    """Simulated process death.  Unlike :class:`InjectedFault` this is
    NOT absorbed by the engine's step watchdog — it propagates out of
    ``step()`` so :func:`serve_with_restarts` (or a real supervisor)
    exercises the snapshot/restore path."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  ``at`` counts per-kind *invocations* for
    reserve/extend (the Nth allocation call fails) and engine *steps*
    for dispatch/draft/crash.  ``count`` is the firing budget: a
    dispatch fault with ``count=2`` fails two consecutive retries of the
    same step before letting it through.  ``rid`` targets poison faults
    at one request (ignored for other kinds)."""

    kind: str
    at: int = 0
    count: int = 1
    rid: int = -1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.count < 1:
            raise ValueError("count must be >= 1")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class FaultPlane:
    """Deterministic, replayable fault injection for the serving stack.

    Construct from a schedule of :class:`FaultSpec` (or
    :meth:`from_schedule` dicts, or :meth:`random` for seeded chaos),
    hand it to ``ContinuousEngine(..., faults=plane)``.  The engine
    wires the seams; the plane only decides *when* to fire and records
    what it fired (``fired``) so failures are replayable via
    :meth:`to_schedule`.

    A single plane may outlive an engine: after an :class:`EngineCrash`
    the restarted engine re-attaches the same plane and the remaining
    schedule keeps counting from where it was — a crash consumed its
    budget and does not re-fire.
    """

    def __init__(self, schedule: Sequence[FaultSpec] = (), *, seed: int = 0):
        self.schedule = tuple(schedule)
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        #: record of every firing (dicts: kind/at/step/rid) — the replay
        #: artifact the chaos suite dumps on failure
        self.fired: list[dict] = []
        #: set by the engine: called with each firing record (emits the
        #: ``fault_injected`` event + counter)
        self.on_fire: Callable[[dict], None] | None = None

        def inj(kinds: tuple[str, ...], expand: bool) -> FailureInjector:
            specs = [s for s in self.schedule if s.kind in kinds]
            if expand:
                # invocation-indexed seams: budget n = the next n calls
                trig = [a for s in specs
                        for a in range(s.at, s.at + s.count)]
                return FailureInjector(tuple(trig), exc=_no_exc)
            triggers = tuple(s.at for s in specs)
            count = max((s.count for s in specs), default=1)
            return FailureInjector(triggers, count=count, exc=_no_exc)

        self._inj_reserve = inj(("reserve",), expand=True)
        self._inj_extend = inj(("extend",), expand=True)
        self._inj_dispatch = inj(("dispatch",), expand=False)
        self._inj_draft = inj(("draft",), expand=False)
        self._inj_crash = inj(("crash",), expand=False)
        self._poison: dict[int, int] = {
            s.rid: s.count for s in self.schedule
            if s.kind == "poison" and s.rid >= 0}
        self._n_reserve = 0
        self._n_extend = 0

    # -- construction helpers ------------------------------------------------

    @classmethod
    def from_schedule(cls, schedule: Sequence[dict], *,
                      seed: int = 0) -> "FaultPlane":
        """Rebuild a plane from :meth:`to_schedule` output (or a
        hand-written list of dicts) — the replay path."""
        return cls([FaultSpec(**{k: v for k, v in d.items()
                                 if k in ("kind", "at", "count", "rid")})
                    for d in schedule], seed=seed)

    def to_schedule(self) -> list[dict]:
        """The schedule as JSON-safe dicts; feed to :meth:`from_schedule`
        (with the same seed) to replay this plane exactly."""
        return [s.to_dict() for s in self.schedule]

    @classmethod
    def random(cls, seed: int, *, rids: Sequence[int] = (),
               horizon: int = 32, n_faults: int = 4) -> "FaultPlane":
        """A seeded random schedule for chaos testing: a mix of
        allocation denials, transient dispatch failures, poisoned
        requests, and (sometimes) one crash, all inside ``horizon``
        engine steps.  Same seed -> same schedule."""
        rng = np.random.default_rng(seed)
        specs: list[FaultSpec] = []
        kinds = ["reserve", "extend", "dispatch", "dispatch", "poison",
                 "crash"]
        crashed = False
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            if kind == "crash":
                if crashed:     # at most one crash per schedule
                    kind = "dispatch"
                else:
                    crashed = True
            at = int(rng.integers(1, max(2, horizon)))
            if kind == "poison" and len(rids):
                rid = int(np.asarray(rids)[int(rng.integers(len(rids)))])
                specs.append(FaultSpec("poison", at=at, rid=rid))
            elif kind in ("reserve", "extend"):
                specs.append(FaultSpec(kind, at=at,
                                       count=int(rng.integers(1, 4))))
            elif kind == "poison":
                specs.append(FaultSpec("dispatch", at=at))
            else:
                specs.append(FaultSpec(kind, at=at))
        return cls(specs, seed=seed)

    # -- firing --------------------------------------------------------------

    def _fire(self, kind: str, **detail) -> None:
        rec = {"kind": kind, **detail}
        self.fired.append(rec)
        if self.on_fire is not None:
            self.on_fire(rec)

    def attach_pool(self, pool) -> None:
        """Wrap ``pool.reserve``/``pool.extend`` with the allocation
        seams.  An injected denial looks exactly like pool exhaustion to
        the caller (``None``/``False`` + a recorded backoff), so every
        existing backoff path — admission retry, lazy-span shrink,
        preemption — is exercised unmodified.  ``extend`` reaches the
        wrapped ``reserve`` internally; the ``extend`` seam exists so a
        schedule can target mid-decode growth without also starving
        admissions."""
        orig_reserve = pool.reserve
        orig_extend = pool.extend

        def reserve(n):
            i = self._n_reserve
            self._n_reserve += 1
            if _fires(self._inj_reserve, i):
                self._fire("reserve", at=i, n=int(n))
                pool.backoffs += 1
                pool._m_backoff.inc()
                return None
            return orig_reserve(n)

        def extend(slot, total_tokens):
            i = self._n_extend
            self._n_extend += 1
            if _fires(self._inj_extend, i):
                self._fire("extend", at=i, slot=int(slot))
                pool.backoffs += 1
                pool._m_backoff.inc()
                return False
            return orig_extend(slot, total_tokens)

        pool.reserve = reserve
        pool.extend = extend

    def before_dispatch(self, kind: str, step: int,
                        rids: Sequence[int]) -> None:
        """Engine seam, called before every jitted dispatch with the
        participating request ids.  Raises :class:`InjectedFault` (poison
        first, then untargeted transients) or :class:`EngineCrash`.
        Raising *before* the dispatch means no host state mutated — the
        engine's retry is a pure re-run of the same step."""
        for rid in rids:
            left = self._poison.get(int(rid), 0)
            if left > 0:
                self._poison[int(rid)] = left - 1
                self._fire("poison", step=int(step), rid=int(rid))
                raise InjectedFault("poison", rid=int(rid), step=int(step))
        if _fires(self._inj_crash, step):
            self._fire("crash", step=int(step))
            raise EngineCrash(f"[injected] engine crash at step {step}")
        if _fires(self._inj_dispatch, step):
            self._fire("dispatch", step=int(step), dispatch=kind)
            raise InjectedFault("dispatch", step=int(step))

    def corrupt_drafts(self, step: int, drafts, vocab: int):
        """Draft-provider seam: replace proposed draft tokens with
        seeded garbage at scheduled steps.  Verification rejects the
        garbage, so this costs speculation efficiency, never
        correctness — the chaos suite's token-identity invariant holds
        through it."""
        if not _fires(self._inj_draft, step):
            return drafts
        self._fire("draft", step=int(step))
        bad = np.asarray(drafts).copy()
        if bad.size:
            bad[...] = self._rng.integers(3, max(4, vocab),
                                          size=bad.shape)
        return bad


def _no_exc(trigger: int) -> BaseException:
    return _Fire(trigger)


class _Fire(Exception):
    """Internal control-flow marker for FailureInjector seams that want
    a boolean ("should this call fail?") rather than an exception."""


def _fires(inj: FailureInjector, value: int) -> bool:
    try:
        inj.maybe_fail(int(value))
    except _Fire:
        return True
    return False


# ---------------------------------------------------------------------------
# Lifecycle-guard configuration + error taxonomy
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ResilienceConfig:
    """Engine lifecycle-guard knobs.  Defaults reproduce the legacy
    behavior exactly (unbounded queue, infinite admission retry, one
    dispatch retry, fixed spec_k), so resilience is opt-in per knob and
    a default config is a behavioral no-op."""

    #: load shedding: submissions beyond this many pending requests get
    #: an immediate terminal ``status="shed"`` Result (None = unbounded)
    max_pending: int | None = None
    #: admission attempts before a request fails terminally
    #: (None = retry forever, the legacy backoff behavior)
    max_admit_retries: int | None = None
    #: engine steps to hold a request after a failed admission; doubles
    #: per consecutive failure (0 = retry every step, legacy)
    admit_backoff_steps: int = 0
    #: consecutive failures of one dispatch kind tolerated before the
    #: participating batch is quarantined
    dispatch_retries: int = 1
    #: adaptive spec_k: halve the live speculation depth when the pool
    #: denies an extend, recover one step of depth per
    #: ``spec_recover_steps`` clean steps
    spec_degrade: bool = False
    spec_recover_steps: int = 8


def classify_error(exc: BaseException) -> str:
    """Stable ``Result.error`` labels: injected faults carry their
    kind, resource exhaustion is ``resource``, pool-invariant breaks are
    ``audit``, anything else its exception type name."""
    if isinstance(exc, InjectedFault):
        return f"injected:{exc.kind}"
    if isinstance(exc, MemoryError):
        return "resource"
    if type(exc).__name__ == "PoolAuditError":
        return "audit"
    return type(exc).__name__


# ---------------------------------------------------------------------------
# Warm-restart driver
# ---------------------------------------------------------------------------

def serve_with_restarts(make_engine: Callable[[], Any],
                        requests: Sequence[Any], *,
                        policy: RestartPolicy | None = None,
                        sleep: Callable[[float], None] | None = None,
                        max_steps: int = 100_000) -> list:
    """Serve ``requests`` to completion across engine crashes.

    ``make_engine`` builds a fresh engine (same config/params each
    time); the driver submits everything to the first engine and pumps
    ``step()``.  When the engine dies (:class:`EngineCrash` from a fault
    plane, or any genuine escape from ``step()``), the dead engine's
    finished Results are drained, its in-flight work snapshotted
    (``engine.snapshot()``), and a fresh engine restores it — re-admitted
    requests resume through the prefix-cache skip-prefill path, so
    greedy outputs are token-identical to an uncrashed run.  The loop,
    restart budget, and backoff come from
    ``runtime.faults.run_with_restarts``; the default policy here is
    zero-backoff (serving restarts are in-process, not a checkpoint
    store stampede).

    Returns one terminal Result per submitted request, in completion
    order.
    """
    results: list = []
    total = len(requests)
    state: dict[str, Any] = {"engine": None, "snap": None}

    def pump(_done: int) -> int:
        eng = state["engine"]
        if eng is None:
            eng = make_engine()
            state["engine"] = eng
            if state["snap"] is not None:
                eng.restore(state["snap"])
                state["snap"] = None
            else:
                for r in requests:
                    eng.submit(r)
        steps = 0
        while len(results) < total:
            eng.step()
            results.extend(eng.drain_results())
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"serve_with_restarts: no completion after "
                    f"{max_steps} steps ({len(results)}/{total} done)")
        return total

    def on_restart(_done: int, _exc: Exception) -> int:
        dead, state["engine"] = state["engine"], None
        if dead is not None:
            # results finished in the dying step are already terminal —
            # never lose them to the crash
            results.extend(dead.drain_results())
            state["snap"] = dead.snapshot()
        return len(results)

    run_with_restarts(
        pump, start_step=0, final_step=total,
        policy=policy or RestartPolicy(backoff_s=0.0),
        on_restart=on_restart,
        sleep=sleep or (lambda _s: None))
    return results
