"""Speculative decoding: draft providers for the multi-token verify step.

The paged engine's decode loop pays one full batched dispatch per
accepted token.  Speculative decoding turns that loop into DRAFT /
VERIFY rounds: a cheap provider proposes up to k next tokens per slot,
the target model scores all of them in ONE ``network.verify_paged_chunk``
call (the chunked-prefill masked ragged layout, so the batch is
``(slots, k+1)`` instead of ``(slots, 1)``), and the engine greedily
accepts the longest draft prefix that matches the target's own argmax —
emitting between 1 and k+1 tokens per dispatch while staying
token-identical to vanilla greedy decode (acceptance only ever shortcuts
steps the target would have taken anyway).  Rejected tail KV is rolled
back host-side: cache cursors via ``network.set_slot_pos``, pool blocks
via ``KVPool.truncate`` (the engine reserves the speculative span lazily
with ``KVPool.extend``, so rejection genuinely returns blocks).

Two providers ship; both are deterministic given the engine state:

  * :class:`NgramDraft` — prompt-lookup ("ngram") drafting: the slot's
    own token history (prompt + produced) is searched for the most
    recent earlier occurrence of its current tail n-gram, and the tokens
    that followed it are proposed.  Model-free, zero extra dispatches —
    the win on repetition-heavy traffic (code edits, RAG quote-backs,
    chat templates), and the paper angle: acceptance turns many
    batch-1-per-slot decode GEMMs into one wider verify GEMM, exactly
    the shape family the schedule cache is built to exploit.
  * :class:`ModelDraft` — a small draft ``ModelConfig`` (e.g. a 0.5B
    drafting for a big target; the serve_bench row self-drafts so
    acceptance is exercised without trained weights) runs k+1 cheap
    decode dispatches to propose, with its OWN paged KV arrays addressed
    through the SAME ``KVPool`` block tables as the target — one
    allocator governs both models, so admission, prefix sharing,
    copy-on-write and truncate stay single-sourced.  The draft mirrors
    every table-affecting engine event through the ``on_*`` hooks below.

Providers see the engine directly (they are engine components, not
plugins crossing a stability boundary): ``propose`` may read slot state
and dispatch draft programs; all TARGET-side mutation stays in the
engine.  Hybrid (mamba2/zamba2) targets and drafts are rejected at
construction — recurrent state has no truncate, so rollback cannot be
made exact (ROADMAP: "SSM state checkpointing" is the missing half;
``KVPool.truncate`` is the attention-side half).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models import network as N
from repro.models.config import ModelConfig
from repro.obs.metrics import Counter

PyTree = object


class DraftProvider:
    """Base provider: the protocol the engine drives.

    ``propose`` is the only required override.  The ``on_*`` hooks mirror
    engine events that touch the shared block tables; providers with
    device-side state (ModelDraft) use them to keep their caches
    coherent, host-only providers (NgramDraft) inherit the no-ops.
    """

    name = "base"

    def bind(self, engine) -> None:
        """Called once at engine construction (pool + caches exist)."""

    def propose(self, engine, slots: list[int],
                ks: dict[int, int]) -> dict[int, list[int]]:
        """Draft up to ``ks[i]`` next tokens for each decoding slot in
        ``slots``; fewer (or none) is always legal — the verify step
        shrinks to what was proposed."""
        raise NotImplementedError

    def on_prefill_chunk(self, engine, toks: np.ndarray, lens: np.ndarray,
                         last_idx: np.ndarray) -> None:
        """A target prefill-chunk batch just ran (same layout/tables)."""

    def on_reset_slot(self, engine, slot: int, pos_value: int) -> None:
        """A slot was (re-)admitted with ``pos_value`` resident tokens."""

    def on_apply_cow(self, engine, src: jax.Array, dst: jax.Array) -> None:
        """COW forks were applied to the target pool; mirror them."""

    def on_rollback(self, engine, pos: np.ndarray) -> None:
        """Post-verify rollback: every slot's accepted resident length."""


class NgramDraft(DraftProvider):
    """Prompt-lookup drafting: propose the continuation that followed the
    most recent earlier occurrence of the slot's current tail n-gram.
    Tries the longest gram first (``n`` down to 1) so a long exact match
    beats a short ambiguous one; no match proposes nothing and the slot
    falls back to a plain 1-token verify that step."""

    name = "ngram"

    def __init__(self, n: int = 3, window: int = 1024):
        if n < 1:
            raise ValueError("ngram n must be >= 1")
        self.n = n
        #: history tokens searched (a bound keeps propose O(window * n))
        self.window = window

    def propose(self, engine, slots, ks):
        out: dict[int, list[int]] = {}
        for i in slots:
            st = engine._slots[i]
            hist = ([int(t) for t in st.req.prompt]
                    + [int(t) for t in st.produced])
            out[i] = self.lookup(hist[-self.window:], ks[i])
        return out

    def lookup(self, hist: list[int], k: int) -> list[int]:
        L = len(hist)
        if k <= 0 or L < 2:
            return []
        for g in range(min(self.n, L - 1), 0, -1):
            pat = hist[L - g:]
            for idx in range(L - g - 1, -1, -1):   # most recent first
                if hist[idx:idx + g] == pat:
                    return hist[idx + g: idx + g + k]
        return []


class ModelDraft(DraftProvider):
    """Small-model drafting over the shared block tables.

    The draft keeps its own paged cache tree (its layers' geometry, the
    target's ``(num_blocks, block_size)`` pool shape) and proposes by
    running ``k+1`` batched greedy decode dispatches: consume the current
    token (emit draft 1), consume draft 1 (emit draft 2), ..., and one
    final consume of the last draft so the draft's KV covers every
    position the target may accept — after rollback both models are
    resident to exactly the accepted length.  Because tables are shared,
    every allocator event (admission, COW fork, truncate, eviction)
    applies to both models by construction; the ``on_*`` hooks only
    mirror the DEVICE-side effects (chunk prefill, block copies, cursor
    resets/rollbacks)."""

    name = "model"

    def __init__(self, cfg: ModelConfig, params: PyTree):
        if cfg.has_recurrent_state:
            raise ValueError(
                f"draft {cfg.name} is a hybrid (SSM) arch: draft state "
                f"rolls back every verify step, and recurrent state "
                f"cannot (see KVPool.truncate — attention-side only)")
        if cfg.is_encoder_only:
            raise ValueError(f"draft {cfg.name} is encoder-only")
        self.cfg = cfg
        self.params = params
        self.caches: PyTree = None
        # draft-dispatch telemetry: standalone counters until bind()
        # re-homes them in the engine's MetricsRegistry (spec.draft_*);
        # ``steps``/``chunk_steps`` stay readable as properties
        self._c_steps = Counter("spec.draft_steps")
        self._c_chunks = Counter("spec.draft_chunk_steps")

    @property
    def steps(self) -> int:
        """Draft decode dispatches (registry-backed; kept as a property
        shim for one PR — read ``spec.draft_steps`` going forward)."""
        return int(self._c_steps.value)

    @property
    def chunk_steps(self) -> int:
        """Draft prefill-chunk dispatches (registry-backed shim — read
        ``spec.draft_chunk_steps`` going forward)."""
        return int(self._c_chunks.value)

    def bind(self, engine) -> None:
        if self.cfg.vocab != engine.cfg.vocab:
            raise ValueError(
                f"draft vocab {self.cfg.vocab} != target vocab "
                f"{engine.cfg.vocab}: drafted ids would be meaningless")
        # re-home the dispatch counters in the engine's registry,
        # carrying any pre-bind counts (a provider re-bound to a fresh
        # engine keeps its lifetime totals)
        prev_s, prev_c = self._c_steps.value, self._c_chunks.value
        self._c_steps = engine.metrics.counter(
            "spec.draft_steps", "draft-model decode dispatches")
        self._c_chunks = engine.metrics.counter(
            "spec.draft_chunk_steps", "draft-model prefill-chunk batches")
        if prev_s:
            self._c_steps.inc(prev_s)
        if prev_c:
            self._c_chunks.inc(prev_c)
        # the engine's per-config jitted-program cache: a restarted engine
        # over the same draft config must not recompile the draft either
        from repro.serving.engine import _engine_fns
        self._fns = _engine_fns(self.cfg, engine.max_len)
        self.caches = N.expand_cache_pos(
            N.init_paged_caches(self.cfg, engine.slots,
                                engine.pool.num_blocks,
                                engine.pool.block_size),
            engine.slots)
        self._key = jax.random.PRNGKey(0)
        self._zero_temps = jnp.zeros((engine.slots,), jnp.float32)

    def on_prefill_chunk(self, engine, toks, lens, last_idx) -> None:
        _, self.caches, self._key = self._fns["prefill_chunk"](
            self.params, jnp.asarray(toks), self.caches, engine._slot_ids,
            engine._bt, jnp.asarray(lens), jnp.asarray(last_idx),
            self._key, self._zero_temps)
        self._c_chunks.inc()

    def on_reset_slot(self, engine, slot, pos_value) -> None:
        self.caches = self._fns["reset_slot"](
            self.caches, jnp.asarray(slot, jnp.int32),
            jnp.asarray(pos_value, jnp.int32))

    def on_apply_cow(self, engine, src, dst) -> None:
        self.caches = self._fns["copy_blocks"](self.caches, src, dst)

    def on_rollback(self, engine, pos) -> None:
        self.caches = self._fns["set_pos"](self.caches,
                                           jnp.asarray(pos, jnp.int32))

    def propose(self, engine, slots, ks):
        out: dict[int, list[int]] = {i: [] for i in slots}
        if not slots:
            return out
        kmax = max(ks[i] for i in slots)
        S = engine.slots
        toks = np.zeros((S, 1), np.int32)
        pos = engine._pos.copy()
        for i in slots:
            toks[i, 0] = engine._slots[i].cur_tok
        # k_i + 1 consumes per slot: the extra one writes the last draft's
        # KV so full acceptance leaves the draft resident too (rows past
        # their budget ride along with adv == 0, writes masked as usual).
        for j in range(kmax + 1):
            adv = np.zeros(S, np.int32)
            for i in slots:
                if j <= ks[i]:
                    adv[i] = 1
            tok, self.caches, self._key = self._fns["decode_sample_paged"](
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(pos), engine._bt, jnp.asarray(adv),
                self._key, self._zero_temps)
            self._c_steps.inc()
            pos += adv
            tok_np = np.asarray(tok)
            for i in slots:
                if j < ks[i]:
                    out[i].append(int(tok_np[i]))
                    toks[i, 0] = int(tok_np[i])
        return out


def make_provider(spec) -> DraftProvider:
    """Normalize the engine's ``spec=`` argument: a provider instance
    passes through; the string ``"ngram"`` builds the model-free default.
    (``"model"`` needs a draft config + params — construct
    :class:`ModelDraft` directly, or use ``launch.serve --spec
    model:<arch>``.)"""
    if isinstance(spec, DraftProvider):
        return spec
    if spec == "ngram":
        return NgramDraft()
    raise ValueError(
        f"unknown spec provider {spec!r}: pass 'ngram' or a DraftProvider "
        f"instance (e.g. spec.ModelDraft(draft_cfg, draft_params))")
