"""deepseek-v2-236b [arXiv:2405.04434; hf]: MLA (kv_lora 512, rope dim 64,
q_lora 1536) + MoE with 2 shared + 160 routed experts top-6
(d_ff_expert 1536); first layer dense (d_ff 12288)."""
from repro.models.config import (BlockKind, MLAConfig, ModelConfig,
                                 MoEConfig)

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    head_dim=128, d_ff=1536, vocab=102400,
    pattern=(BlockKind.ATTN,),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                  n_shared_experts=2, d_ff_shared=3072),
    first_layer_dense_ff=12288,
)
