"""llama4-scout-17b-16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]:
MoE 16 routed experts top-1 + shared expert on every layer; GQA kv=8,
head_dim 128.  iRoPE/chunked-attention and early-fusion vision are
approximated as standard RoPE + text-only (noted in DESIGN.md)."""
from repro.models.config import BlockKind, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    head_dim=128, d_ff=8192, vocab=202048,
    pattern=(BlockKind.ATTN,),
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                  n_shared_experts=1, d_ff_shared=8192),
    rope_theta=5e5,
)
