"""Architecture registry: ``get(name)`` -> ModelConfig; ``--arch`` ids.

One module per assigned architecture (exact public-literature configs) plus
the paper's own evaluation config (``gta_paper``).  Input-shape sets live in
``repro.configs.shapes``.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS: list[str] = [
    "qwen1_5_4b",
    "gemma2_9b",
    "qwen2_0_5b",
    "chatglm3_6b",
    "llava_next_mistral_7b",
    "zamba2_7b",
    "llama4_scout_17b_a16e",
    "deepseek_v2_236b",
    "hubert_xlarge",
    "mamba2_2_7b",
]

#: accepted aliases (the assignment's dashed ids)
ALIASES: dict[str, str] = {
    "qwen1.5-4b": "qwen1_5_4b",
    "gemma2-9b": "gemma2_9b",
    "qwen2-0.5b": "qwen2_0_5b",
    "chatglm3-6b": "chatglm3_6b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-7b": "zamba2_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-2.7b": "mamba2_2_7b",
}


def get(name: str) -> ModelConfig:
    key = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG.validate()


def all_configs() -> dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}
