"""mamba2-2.7b [arXiv:2405.21060; unverified]: attention-free SSD stack
(d_ff=0 — no MLP blocks; each layer is one Mamba2 block with expand=2,
d_state=128, head_dim 64)."""
from repro.models.config import BlockKind, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    pattern=(BlockKind.MAMBA2,),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
)
