"""zamba2-7b [arXiv:2411.15242; unverified]: Mamba2 backbone with shared
attention blocks.  Modeled as 13 repeats of [5x mamba2 + shared-attn] plus a
3-layer mamba2 tail = 81 layers; the shared attention alternates between 2
weight sets (the paper's 'two alternating shared blocks')."""
from repro.models.config import BlockKind, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, head_dim=112,
    pattern=(BlockKind.MAMBA2,) * 5 + (BlockKind.SHARED_ATTN,),
    tail=(BlockKind.MAMBA2,) * 3,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1),
    n_shared_attn_sets=2,
)
