"""The GTA paper's own evaluation setting (Table 1): the 4-lane GTA instance
and the area-parity baselines — used by benchmarks/, not a neural net."""
from repro.core.scheduler import GTAConfig

GTA_4LANE = GTAConfig(lanes=4)
