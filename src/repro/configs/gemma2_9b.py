"""gemma2-9b [arXiv:2408.00118; hf]: alternating local(4096)/global
attention, logit softcaps, GeGLU, sandwich norms, head_dim 256,
scaled embeddings, tied head."""
from repro.models.config import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    head_dim=256, d_ff=14336, vocab=256000,
    pattern=(BlockKind.ATTN_LOCAL, BlockKind.ATTN),
    local_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norms=True, act="gelu",
    tie_embeddings=True, scale_embeddings=True,
)
