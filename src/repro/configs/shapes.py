"""Assigned input-shape sets, cell applicability, and ShapeDtypeStruct
stand-ins for the dry-run (no allocation).

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> serve prefill
  decode_32k   ctx 32,768  global_batch 128   -> serve_step (1 new token)
  long_500k    ctx 524,288 global_batch 1     -> serve_step (1 new token)

Skips (recorded in DESIGN.md §Arch-applicability):
  * decode shapes for encoder-only archs (hubert) — no autoregressive step;
  * long_500k for pure full-attention archs — needs sub-quadratic context
    state; runs for SSM/hybrid (mamba2, zamba2).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SHAPE_IDS = tuple(SHAPES)


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """None if the (arch, shape) cell runs; otherwise why it is skipped."""
    if cfg.is_encoder_only and shape.mode in ("decode",):
        return "encoder-only: no autoregressive decode step"
    if cfg.is_encoder_only and shape.name == "prefill_32k":
        # encoders do have a full forward at 32k — keep it (it exercises the
        # non-causal blockwise attention); only decode shapes are undefined.
        return None
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: 524k dense-KV decode is gated by "
                "global attention layers (see DESIGN.md §4)")
    return None


def live_cells() -> list[tuple[str, str]]:
    """All (arch, shape) pairs that run (31 of the 40)."""
    from repro import configs as C
    out = []
    for a in C.ARCH_IDS:
        cfg = C.get(a)
        for s in SHAPE_IDS:
            if skip_reason(cfg, SHAPES[s]) is None:
                out.append((a, s))
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; weak-type-correct, shardable)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_spec(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """The batch pytree for train_step / loss_fn."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "frames":
        return {"frames": _sds((B, S, cfg.d_model), jnp.bfloat16),
                "labels": _sds((B, S), jnp.int32)}
    if cfg.frontend == "patches":
        P = cfg.frontend_prefix_len
        return {"tokens": _sds((B, S - P), jnp.int32),
                "patches": _sds((B, P, cfg.d_model), jnp.bfloat16),
                "labels": _sds((B, S - P), jnp.int32)}
    return {"tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32)}


def prefill_batch_spec(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "frames":
        return {"frames": _sds((B, S, cfg.d_model), jnp.bfloat16)}
    if cfg.frontend == "patches":
        P = cfg.frontend_prefix_len
        return {"tokens": _sds((B, S - P), jnp.int32),
                "patches": _sds((B, P, cfg.d_model), jnp.bfloat16)}
    return {"tokens": _sds((B, S), jnp.int32)}


def decode_token_spec(cfg: ModelConfig, shape: ShapeSpec):
    return _sds((shape.global_batch, 1), jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Everything the corresponding step function takes (minus params/cache)."""
    shape = SHAPES[shape_name]
    if shape.mode == "train":
        return {"batch": train_batch_spec(cfg, shape)}
    if shape.mode == "prefill":
        return {"batch": prefill_batch_spec(cfg, shape)}
    return {"tokens": decode_token_spec(cfg, shape)}
