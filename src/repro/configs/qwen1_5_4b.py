"""qwen1.5-4b [hf:Qwen/Qwen1.5-4B; hf]: dense, QKV bias, effectively MHA
(kv == heads per the assignment)."""
from repro.models.config import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab=151936,
    pattern=(BlockKind.ATTN,),
    qkv_bias=True,
    rope_theta=1e6,  # qwen1.5 long-rope base
)
