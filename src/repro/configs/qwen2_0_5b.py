"""qwen2-0.5b [arXiv:2407.10671; hf]: GQA kv=2, QKV bias, tied embeddings."""
from repro.models.config import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151936,
    pattern=(BlockKind.ATTN,),
    qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
)
