"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]:
Mistral-7B text backbone (GQA kv=8, SWA 4096 interleaved as in Mistral
v0.1 — modeled as local attention on all layers per Mistral) with an anyres
vision frontend STUB: `input_specs()` supplies precomputed patch embeddings
(projected by a learned adapter); 576 base + anyres grid tokens prefix the
text sequence."""
from repro.models.config import BlockKind, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000,
    pattern=(BlockKind.ATTN_LOCAL,),
    local_window=4096,
    frontend="patches", frontend_prefix_len=1152,  # 576 base + 576 anyres
)
