"""hubert-xlarge [arXiv:2106.07447; unverified]: encoder-only (bidirectional,
non-causal) transformer over precomputed frame embeddings (the CNN feature
extractor is the STUB frontend); frame-level classification head over 504
cluster targets.  No decode shapes (no autoregressive step)."""
from repro.models.config import BlockKind, ModelConfig, RopeMode

CONFIG = ModelConfig(
    name="hubert-xlarge",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504,
    pattern=(BlockKind.ATTN,),
    causal=False, rope_mode=RopeMode.NONE,
    frontend="frames", act="gelu",
)
