"""chatglm3-6b [arXiv:2406.12793; hf]: 2d RoPE (rotary over half the head
dims), GQA kv=2."""
from repro.models.config import BlockKind, ModelConfig, RopeMode

CONFIG = ModelConfig(
    name="chatglm3-6b",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024,
    pattern=(BlockKind.ATTN,),
    rope_mode=RopeMode.HALF,
    qkv_bias=True,  # chatglm: bias on qkv only
)
