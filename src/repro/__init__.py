"""repro: GTA (General Tensor Accelerator) as a production JAX framework.

The paper's contribution (multi-precision-as-GEMM, p-GEMM classification,
dataflow/precision/array-resize scheduling) lives in ``repro.core`` and
``repro.kernels``; the surrounding training/serving framework exercises it
across 10 architectures on a multi-pod TPU mesh.
"""
__version__ = "1.0.0"
