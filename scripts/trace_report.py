#!/usr/bin/env python
"""Render a serving trace: per-request waterfall + dispatch drift table.

Input is the Chrome trace-event JSON written by ``--trace-out``
(``repro.obs.events.Tracer.export``).  Three sections:

  * structural validation (``--validate`` exits nonzero on a malformed
    trace or when an expected dispatch is missing from the profile);
  * a per-request ASCII waterfall from the lifecycle events — queued
    (submit→admit ``-``), prefill (admit→first-token ``=``), decode
    (first-token→finish ``#``), with preempt/resume marked ``!``/``r``;
  * a modeled-vs-measured drift table from the profiled dispatch spans
    (``launch.serve --profile``): per dispatch, mean measured wall vs
    the ScheduleCache cycle model.  The model predicts RELATIVE cost —
    cycles, not seconds — so the table derives one global seconds-per-
    cycle scale (the median across dispatches) and reports each
    dispatch's drift from that fit; per-shape sub-rows apportion the
    measured mean by modeled cycle share.  See docs/OBSERVABILITY.md
    for how to read it.

    PYTHONPATH=src python scripts/trace_report.py \
        experiments/obs/trace_smoke.json \
        --metrics experiments/obs/metrics_smoke.json --validate
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.events import validate_chrome_trace  # noqa: E402
from repro.obs.profile import DISPATCH_NAMES  # noqa: E402
from repro.planner.calibrate import (calibration_from_events,  # noqa: E402
                                     dispatch_spans, drift_rows,
                                     fit_ns_per_cycle)

WATERFALL_WIDTH = 60


def _lifecycle_by_rid(events: list[dict]) -> dict[int, list[dict]]:
    out: dict[int, list[dict]] = {}
    for ev in events:
        if ev.get("ph") == "M" or ev.get("cat") not in ("lifecycle",):
            continue
        rid = ev.get("args", {}).get("rid", -1)
        if rid is None or rid < 0:
            continue
        out.setdefault(rid, []).append(ev)
    for evs in out.values():
        evs.sort(key=lambda e: e["ts"])
    return out


def render_waterfall(events: list[dict]) -> list[str]:
    """ASCII per-request timeline (one row per rid, run-relative µs)."""
    by_rid = _lifecycle_by_rid(events)
    if not by_rid:
        return ["(no per-request lifecycle events in trace)"]
    t_lo = min(e["ts"] for evs in by_rid.values() for e in evs)
    t_hi = max(e["ts"] + e.get("dur", 0.0)
               for evs in by_rid.values() for e in evs)
    span = max(t_hi - t_lo, 1e-9)

    def col(ts: float) -> int:
        return min(WATERFALL_WIDTH - 1,
                   int((ts - t_lo) / span * WATERFALL_WIDTH))

    lines = [f"-- request waterfall ({len(by_rid)} requests, "
             f"{span/1e3:.1f} ms span; '-' queued, '=' prefill, "
             f"'#' decode, '!' preempt, 'r' resume) --"]
    hdr = (f"{'rid':>4} {'slot':>4} {'queue_ms':>9} {'ttft_ms':>8} "
           f"{'total_ms':>9} {'tok':>4}  timeline")
    lines.append(hdr)
    for rid in sorted(by_rid):
        evs = by_rid[rid]
        stamp = {}
        slots, preempts, resumes = set(), [], []
        tokens = 0
        for e in evs:
            name = e["name"]
            if name in ("submit", "admit", "first_token", "finish"):
                stamp.setdefault(name, e["ts"])
            if name == "preempt":
                preempts.append(e["ts"])
            if name == "resume":
                resumes.append(e["ts"])
                stamp.setdefault("admit", e["ts"])
            s = e.get("args", {}).get("slot", e.get("tid", 0) - 100)
            if name != "submit" and 0 <= s < 100:
                slots.add(s)
            if name == "finish":
                tokens = e.get("args", {}).get("tokens", 0)
        t_sub = stamp.get("submit", t_lo)
        t_adm = stamp.get("admit", t_sub)
        t_first = stamp.get("first_token", t_adm)
        t_fin = stamp.get("finish", t_hi)
        bar = [" "] * WATERFALL_WIDTH
        for i in range(col(t_sub), col(t_adm)):
            bar[i] = "-"
        for i in range(col(t_adm), col(t_first)):
            bar[i] = "="
        for i in range(col(t_first), col(t_fin) + 1):
            bar[i] = "#"
        bar[col(t_adm)] = "="
        for ts in preempts:
            bar[col(ts)] = "!"
        for ts in resumes:
            bar[col(ts)] = "r"
        slot_s = ",".join(str(s) for s in sorted(slots)) or "-"
        lines.append(
            f"{rid:>4} {slot_s:>4} {(t_adm - t_sub)/1e3:>9.2f} "
            f"{(t_first - t_sub)/1e3:>8.2f} {(t_fin - t_sub)/1e3:>9.2f} "
            f"{tokens:>4}  |{''.join(bar)}|")
    return lines


def render_drift(events: list[dict], *, shapes: bool = True) -> list[str]:
    """Modeled-vs-measured drift table (module docstring).  The row
    grouping and the median ns/cycle fit live in
    ``repro.planner.calibrate`` — the planner's calibration is the same
    fit this table renders."""
    rows = drift_rows(events)
    if not rows:
        return ["(no profiled dispatch spans — rerun with --profile)"]
    scale = fit_ns_per_cycle(rows)

    lines = [f"-- dispatch drift table (modeled cycles vs measured wall; "
             f"fit {scale:.2f} ns/cycle median) --"]
    lines.append(f"{'dispatch':<22}{'n':>5}{'cal':>5}{'meas_us':>10}"
                 f"{'model_kcyc':>12}{'ns/cyc':>8}{'drift%':>8}"
                 f"{'GB/s_model':>11}")
    for r in sorted(rows, key=lambda r: -r["mean_us"]):
        if r["cycles"] > 0 and scale > 0:
            pred_us = r["cycles"] * scale / 1e3
            drift = (r["mean_us"] - pred_us) / pred_us * 100.0
            ns_cyc = r["mean_us"] * 1e3 / r["cycles"]
        else:
            drift = ns_cyc = 0.0
        gbs = (r["traffic"] / (r["mean_us"] * 1e-6) / 1e9
               if r["mean_us"] > 0 else 0.0)
        lines.append(f"{r['name']:<22}{r['n_serve']:>5}{r['n_cal']:>5}"
                     f"{r['mean_us']:>10.1f}{r['cycles']/1e3:>12.1f}"
                     f"{ns_cyc:>8.2f}{drift:>+8.1f}{gbs:>11.2f}")
        if shapes and r["shape_cycles"]:
            for M, N, K, count, cyc in r["shape_cycles"]:
                share = count * cyc / max(r["cycles"], 1e-9)
                lines.append(
                    f"    {M:>5} x {N:>5} x {K:>5}  x{count:<3} "
                    f"{count*cyc/1e3:>10.1f} kcyc  {share*100:>5.1f}%  "
                    f"~{r['mean_us']*share:>8.1f} us")
    lines.append("(drift% is deviation from the median ns/cycle fit — "
                 "the cycle model predicts relative, not absolute, cost)")
    return lines


def render_metrics(path: str) -> list[str]:
    with open(path) as f:
        snap = json.load(f)
    lines = [f"-- metrics snapshot ({path}) --"]
    c = snap.get("counters", {})
    for k in sorted(c):
        if k.startswith(("engine.", "spec.", "schedule.", "kv_pool.")):
            lines.append(f"  {k:<32}{c[k]:>12.0f}")
    for name, h in sorted(snap.get("histograms", {}).items()):
        lines.append(f"  {name:<32}{h['count']:>6.0f} obs   "
                     f"p50 {h['p50']:>10.1f}   p95 {h['p95']:>10.1f}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace JSON from --trace-out")
    ap.add_argument("--metrics", default=None,
                    help="metrics snapshot JSON from --metrics-out")
    ap.add_argument("--validate", action="store_true",
                    help="exit nonzero on a malformed trace or missing "
                         "expected dispatches")
    ap.add_argument("--expect-dispatches",
                    default=",".join(DISPATCH_NAMES),
                    help="comma list the drift table must cover under "
                         "--validate (default: all four hot dispatches; "
                         "pass a narrower list for e.g. hybrid configs "
                         "with no verify dispatch)")
    ap.add_argument("--no-shapes", action="store_true",
                    help="suppress per-shape sub-rows")
    ap.add_argument("--calibration-out", default=None,
                    help="write a planner calibration JSON (ns/cycle + "
                         "per-dispatch overheads) fitted from this "
                         "trace's profiled spans — see docs/PLANNER.md")
    args = ap.parse_args(argv)

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"trace_report: cannot read {args.trace}: {e}")
        return 1

    failures = []
    if args.validate:
        errs = validate_chrome_trace(doc)
        if errs:
            failures += [f"invalid trace: {e}" for e in errs[:10]]
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []

    dropped = 0
    if isinstance(doc, dict):
        dropped = doc.get("otherData", {}).get("dropped_events", 0)
    n_life = sum(1 for e in events if e.get("cat") == "lifecycle")
    n_disp = sum(1 for e in events if e.get("cat") == "dispatch")
    print(f"[trace_report] {args.trace}: {len(events)} events "
          f"({n_life} lifecycle, {n_disp} dispatch, {dropped} dropped)")

    for line in render_waterfall(events):
        print(line)
    print()
    for line in render_drift(events, shapes=not args.no_shapes):
        print(line)

    if args.validate:
        have = set(dispatch_spans(events))
        want = [s for s in args.expect_dispatches.split(",") if s]
        missing = [n for n in want if n not in have]
        if missing:
            failures.append(
                f"drift table missing expected dispatches: {missing} "
                f"(have {sorted(have)}) — was the run profiled?")

    if args.calibration_out:
        try:
            cal = calibration_from_events(
                events, meta={"source": args.trace})
            cal.save(args.calibration_out)
            print(f"[trace_report] calibration "
                  f"({cal.ns_per_cycle:.2f} ns/cycle, "
                  f"{len(cal.overhead_us)} dispatches) "
                  f"-> {args.calibration_out}")
        except (ValueError, OSError) as e:
            failures.append(f"cannot export calibration: {e}")

    if args.metrics:
        print()
        try:
            for line in render_metrics(args.metrics):
                print(line)
        except (OSError, json.JSONDecodeError, KeyError) as e:
            failures.append(f"cannot read metrics {args.metrics}: {e}")

    for msg in failures:
        print(f"FAIL: {msg}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
