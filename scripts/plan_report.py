#!/usr/bin/env python
"""Capacity-planner CLI: drift reports and what-if queries from a trace.

Front-end for ``repro.planner`` (docs/PLANNER.md).  Every subcommand
starts from a Chrome-trace export of a profiled serve run
(``launch.serve --profile --trace-out ...``): the trace carries both
the measured side (lifecycle events) and the calibration input
(dispatch spans), and the engine geometry is restated on the command
line because a trace does not embed it.

    # model-vs-measured drift on the smoke trace (CI runs this)
    PYTHONPATH=src python scripts/plan_report.py drift \
        experiments/obs/trace_smoke.json \
        --arch qwen2-0.5b --scaled-down --slots 2 --max-len 96 --spec

    # fleet sizing: how does TTFT p95 scale over replica counts?
    PYTHONPATH=src python scripts/plan_report.py sweep TRACE \
        --arch qwen2-0.5b --scaled-down --slots 2 --max-len 96 \
        --replicas 1,2,4,8

    # admission frontier: highest arrival rate that meets a 50ms TTFT SLO
    PYTHONPATH=src python scripts/plan_report.py frontier TRACE \
        --arch qwen2-0.5b --scaled-down --slots 2 --max-len 96 \
        --rates 20,50,100,200 --slo-ms 50

    # memory provisioning: smallest KV pool within 10% of baseline TTFT
    PYTHONPATH=src python scripts/plan_report.py headroom TRACE \
        --arch qwen2-0.5b --scaled-down --slots 2 --max-len 96
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro import configs as CONFIGS  # noqa: E402
from repro.planner import (Calibration, EngineGeometry,  # noqa: E402
                           WorkloadModel, admission_frontier,
                           calibration_from_events, pool_headroom,
                           requests_from_trace, sweep_replicas)
from repro.planner.model import VERIFY, measured_latencies  # noqa: E402


def estimate_accept_len(events: list[dict]) -> float:
    """Expected tokens per verify dispatch, from the trace itself:
    decoded tokens over serve-kind verify spans (>= 1.0)."""
    n_verify = sum(1 for e in events
                   if e.get("cat") == "dispatch" and e.get("ph") == "X"
                   and e.get("args", {}).get("dispatch") == VERIFY
                   and e.get("args", {}).get("kind", "serve") == "serve")
    decoded = sum(max(m["tokens"] - 1, 0)
                  for m in measured_latencies(events).values())
    if n_verify <= 0 or decoded <= 0:
        return 1.0
    return max(decoded / n_verify, 1.0)


def build(args, events):
    cfg = CONFIGS.get(args.arch)
    if args.scaled_down:
        cfg = cfg.scaled_down()
    geom = EngineGeometry(slots=args.slots, max_len=args.max_len,
                          prefill_chunk=min(args.prefill_chunk,
                                            args.max_len),
                          block_size=args.block_size,
                          kv_blocks=args.kv_blocks,
                          spec=args.spec, spec_k=args.spec_k,
                          precision=args.precision)
    model = WorkloadModel(cfg, geom)
    if args.calibration:
        cal = Calibration.load(args.calibration)
    else:
        cal = calibration_from_events(events, meta={"source": args.trace})
    acc = args.accept_len
    if acc is None:
        acc = estimate_accept_len(events) if args.spec else 1.0
    return model, cal, acc


def cmd_drift(args, events) -> int:
    model, cal, acc = build(args, events)
    specs = requests_from_trace(events)
    if not specs:
        print("plan_report: no finished requests in trace")
        return 1
    meas = measured_latencies(events)
    plan = model.simulate(specs, calibration=cal, accept_len=acc)
    ttft = [meas[s.rid]["ttft_us"] for s in specs]
    tpot = [meas[s.rid]["tpot_us"] for s in specs if meas[s.rid]["tpot_us"]]
    p95_meas = float(np.percentile(ttft, 95))
    tpot_meas = float(np.mean(tpot)) if tpot else 0.0
    report = {
        "requests": len(specs),
        "accept_len": round(acc, 3),
        "ns_per_cycle": round(cal.ns_per_cycle, 3),
        "startup_us": round(cal.startup_us, 1),
        "host_us_per_dispatch": round(cal.host_us_per_dispatch, 2),
        "ttft_p95_modeled_us": round(plan.p95_ttft_us(), 1),
        "ttft_p95_measured_us": round(p95_meas, 1),
        "ttft_p95_drift": round(plan.p95_ttft_us() / p95_meas - 1.0, 4)
                          if p95_meas > 0 else None,
        "tpot_modeled_us": round(plan.mean_tpot_us(), 1),
        "tpot_measured_us": round(tpot_meas, 1),
        "tpot_drift": round(plan.mean_tpot_us() / tpot_meas - 1.0, 4)
                      if tpot_meas > 0 else None,
        "steps_modeled": plan.steps,
        "chunk_steps_modeled": plan.chunk_steps,
        "peak_blocks_modeled": plan.peak_blocks,
        "avg_pool_util_modeled": round(plan.avg_pool_util, 4),
    }
    print(f"-- planner drift ({args.trace}: {report['requests']} "
          f"requests, accept_len {report['accept_len']}) --")
    for k in ("ttft_p95", "tpot"):
        d = report[f"{k}_drift"]
        print(f"  {k:<10} modeled {report[f'{k}_modeled_us']:>10.1f} us   "
              f"measured {report[f'{k}_measured_us']:>10.1f} us   "
              f"drift {d*100:+.1f}%" if d is not None else
              f"  {k:<10} unmeasurable in this trace")
    print(f"  dispatch counts: {plan.steps} decode/verify + "
          f"{plan.chunk_steps} chunk batches; peak "
          f"{plan.peak_blocks} blocks, avg pool util "
          f"{plan.avg_pool_util:.2f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"  -> {args.out}")
    if args.max_drift is not None:
        bad = [k for k in ("ttft_p95_drift", "tpot_drift")
               if report[k] is not None and abs(report[k]) > args.max_drift]
        if bad:
            print(f"FAIL: {', '.join(bad)} outside "
                  f"±{args.max_drift*100:.0f}%")
            return 1
    return 0


def cmd_sweep(args, events) -> int:
    model, cal, acc = build(args, events)
    specs = requests_from_trace(events)
    counts = [int(x) for x in args.replicas.split(",")]
    rows = sweep_replicas(model, specs, counts, calibration=cal,
                          accept_len=acc)
    print(f"-- replica sweep ({len(specs)} requests) --")
    print(f"{'replicas':>9}{'p95_ttft_ms':>13}{'tpot_ms':>9}"
          f"{'makespan_ms':>13}{'util':>7}{'peak_blk':>9}")
    for r in rows:
        print(f"{r['replicas']:>9}{r['p95_ttft_us']/1e3:>13.1f}"
              f"{r['mean_tpot_us']/1e3:>9.2f}"
              f"{r['makespan_us']/1e3:>13.1f}"
              f"{r['avg_pool_util']:>7.2f}{r['peak_blocks']:>9}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"  -> {args.out}")
    return 0


def cmd_frontier(args, events) -> int:
    model, cal, acc = build(args, events)
    specs = requests_from_trace(events)
    rates = [float(x) for x in args.rates.split(",")]
    slo_us = args.slo_ms * 1e3 if args.slo_ms is not None else None
    rows = admission_frontier(model, specs, rates,
                              n_requests=args.n_requests, slo_us=slo_us,
                              calibration=cal, accept_len=acc)
    print(f"-- admission frontier ({args.n_requests} synthesized "
          f"requests per rate) --")
    print(f"{'req/s':>8}{'p95_ttft_ms':>13}{'tpot_ms':>9}{'util':>7}"
          f"{'slo':>5}")
    frontier = None
    for r in rows:
        met = r.get("slo_met")
        print(f"{r['rate_per_s']:>8.1f}{r['p95_ttft_us']/1e3:>13.1f}"
              f"{r['mean_tpot_us']/1e3:>9.2f}{r['avg_pool_util']:>7.2f}"
              f"{'' if met is None else ('  ok' if met else ' MISS'):>5}")
        if met:
            frontier = r["rate_per_s"]
    if slo_us is not None:
        print(f"  admission frontier: "
              f"{frontier if frontier is not None else 'none'} req/s "
              f"under a {args.slo_ms:.0f}ms TTFT p95 SLO")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"  -> {args.out}")
    return 0


def cmd_headroom(args, events) -> int:
    model, cal, acc = build(args, events)
    specs = requests_from_trace(events)
    rep = pool_headroom(model, specs, tolerance=args.tolerance,
                        calibration=cal, accept_len=acc)
    print(f"-- pool headroom (tolerance {args.tolerance:.0%}) --")
    print(f"  provisioned {rep['pool_blocks']} blocks, modeled peak "
          f"{rep['peak_blocks']}, baseline TTFT p95 "
          f"{rep['baseline_p95_ttft_us']/1e3:.1f}ms")
    print(f"  smallest pool within tolerance: {rep['min_blocks']} blocks "
          f"-> headroom {rep['headroom_blocks']} blocks "
          f"({rep['headroom_frac']:.0%})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep, f, indent=2)
        print(f"  -> {args.out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    common = argparse.ArgumentParser(add_help=False)
    common.add_argument("trace", help="Chrome trace JSON from --trace-out")
    common.add_argument("--arch", default="qwen2-0.5b")
    common.add_argument("--scaled-down", action="store_true")
    common.add_argument("--slots", type=int, default=4)
    common.add_argument("--max-len", type=int, default=160)
    common.add_argument("--prefill-chunk", type=int, default=32)
    common.add_argument("--block-size", type=int, default=16)
    common.add_argument("--kv-blocks", type=int, default=None)
    common.add_argument("--spec", action="store_true",
                        help="model the speculative verify path")
    common.add_argument("--spec-k", type=int, default=4)
    common.add_argument("--precision", default="FP32")
    common.add_argument("--accept-len", type=float, default=None,
                        help="expected tokens per verify dispatch "
                             "(default: estimated from the trace)")
    common.add_argument("--calibration", default=None,
                        help="calibration JSON (trace_report.py "
                             "--calibration-out); default fits from the "
                             "trace itself")
    common.add_argument("--out", default=None, help="write report JSON")

    p = sub.add_parser("drift", parents=[common],
                       help="model-vs-measured TTFT/TPOT drift")
    p.add_argument("--max-drift", type=float, default=None,
                   help="exit nonzero when |drift| exceeds this fraction")
    p.set_defaults(fn=cmd_drift)
    p = sub.add_parser("sweep", parents=[common],
                       help="replica-count sweep")
    p.add_argument("--replicas", default="1,2,4")
    p.set_defaults(fn=cmd_sweep)
    p = sub.add_parser("frontier", parents=[common],
                       help="admission-rate frontier")
    p.add_argument("--rates", default="10,20,50,100")
    p.add_argument("--slo-ms", type=float, default=None)
    p.add_argument("--n-requests", type=int, default=32)
    p.set_defaults(fn=cmd_frontier)
    p = sub.add_parser("headroom", parents=[common],
                       help="KV-pool headroom search")
    p.add_argument("--tolerance", type=float, default=0.1)
    p.set_defaults(fn=cmd_headroom)

    args = ap.parse_args(argv)
    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"plan_report: cannot read {args.trace}: {e}")
        return 1
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    try:
        return args.fn(args, events)
    except ValueError as e:
        print(f"plan_report: {e}")
        return 1


if __name__ == "__main__":
    sys.exit(main())
