#!/usr/bin/env python
"""gta-lint: run the static verifier suite over registered configs.

Three passes (see ``src/repro/analysis/``):

  schedule  every engine-registered GEMM shape's resolved schedule is
            checked for fold divisibility, VMEM residency (incl. the OS
            accumulator plane), revisit-accumulate safety, and exact
            grid coverage — per config, per precision path.
  jaxpr     the engine's pre-resolved hot dispatches (decode step,
            prefill_paged_chunk, verify_paged_chunk, head_apply) are
            traced abstractly and screened for zero-cost dispatches,
            silent fp32 promotion in quant paths, host transfers,
            scalar leakage, baked constants, outsized intermediates.
  pool      bounded-exhaustive model check of KVPool op sequences
            against the refcount invariants (config-independent; runs
            once, not per config).

Findings are matched against the committed baseline
(``scripts/gta_lint_baseline.json``); any finding NOT in the baseline
exits 1.  CI runs this over every config in ``repro.configs``:

    python scripts/gta_lint.py                       # all configs, all passes
    python scripts/gta_lint.py --configs qwen2_0_5b --passes schedule,jaxpr
    python scripts/gta_lint.py --json                # machine-readable
    python scripts/gta_lint.py --write-baseline      # accept current findings
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__),
                                "gta_lint_baseline.json")


def main(argv=None) -> int:
    from repro.analysis import (PASS_NAMES, load_baseline, split_suppressed,
                                write_baseline)
    from repro.configs import ARCH_IDS, get

    ap = argparse.ArgumentParser(description="GTA static verifier suite")
    ap.add_argument("--configs", default=None,
                    help="comma-separated arch ids (default: all registered)")
    ap.add_argument("--passes", default=",".join(PASS_NAMES),
                    help=f"comma-separated subset of {PASS_NAMES}")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="suppression file (missing = empty baseline)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into --baseline")
    ap.add_argument("--max-states", type=int, default=50_000,
                    help="pool model-checker state budget")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON on stdout")
    args = ap.parse_args(argv)

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    unknown = set(passes) - set(PASS_NAMES)
    if unknown:
        ap.error(f"unknown pass(es) {sorted(unknown)}; choose from "
                 f"{PASS_NAMES}")
    names = ([c.strip() for c in args.configs.split(",") if c.strip()]
             if args.configs else list(ARCH_IDS))

    findings = []
    t0 = time.time()

    if "schedule" in passes:
        from repro.analysis.schedule_check import check_config as p1
        for name in names:
            findings += p1(get(name))
    if "jaxpr" in passes:
        import dataclasses

        from repro.analysis.jaxpr_lint import check_config as p2
        for name in names:
            cfg = get(name)
            findings += p2(cfg)
            # quantized-serving variant: the same hot dispatches traced
            # with QuantTensor weights and int8 KV pools — this is the
            # config family the quant-fp32-promotion rule exists for,
            # and the registry configs never set quant_serving
            if not cfg.is_encoder_only:
                findings += p2(dataclasses.replace(
                    cfg, quant_serving=True,
                    name=cfg.name + "+int8").validate())
    if "pool" in passes:
        from repro.analysis.pool_model import ModelCheckConfig, check_pool
        findings += check_pool(ModelCheckConfig(),
                               max_states=args.max_states)

    if args.write_baseline:
        write_baseline(findings, args.baseline)
        print(f"wrote {len(findings)} suppression(s) to {args.baseline}")
        return 0

    fresh, known = split_suppressed(findings, load_baseline(args.baseline))
    dt = time.time() - t0
    if args.json:
        print(json.dumps({
            "configs": names, "passes": passes, "seconds": round(dt, 2),
            "unsuppressed": [f.to_dict() for f in fresh],
            "suppressed": [f.to_dict() for f in known]}, indent=2))
    else:
        for f in fresh:
            print(f.format())
        for f in known:
            print(f"[suppressed] {f.format()}")
        print(f"gta-lint: {len(names)} config(s), passes={passes}: "
              f"{len(fresh)} unsuppressed, {len(known)} suppressed "
              f"finding(s) in {dt:.1f}s")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
