#!/usr/bin/env python
"""Deterministic test-file sharding for the CI full-suite matrix.

The full tier-1 suite is ~11-15 min single-process — too long for one
CI job's timeout with headroom — so the ``full-tests`` matrix splits
the test FILES across workers.  Assignment is longest-processing-time
greedy over a measured weight table (seconds on the dev box; unknown
files get a conservative default so new test files are picked up
automatically and never silently dropped): every file in
``tests/test_*.py`` lands in exactly one shard, deterministically.

    python scripts/ci_shard.py --shard 1 --num-shards 3   # file list
    python scripts/ci_shard.py --list                     # full table

The script is import-free of the repo (pure stdlib) so it runs before
dependencies are installed.
"""

from __future__ import annotations

import argparse
import glob
import os
import sys

#: measured single-file wall seconds (dev box, 2026-07); refresh when a
#: shard nears its CI timeout.  Files absent here get DEFAULT_WEIGHT.
WEIGHTS = {
    "test_models.py": 470,
    "test_serving_engine.py": 180,
    "test_chaos.py": 90,
    "test_system.py": 58,
    "test_kernels.py": 53,
    "test_spec.py": 40,
    "test_obs.py": 40,
    "test_gemm_backend.py": 34,
    "test_substrates.py": 24,
    "test_paged_attention.py": 21,
    "test_quant_serving.py": 40,
    "test_moe_distributed.py": 15,
    "test_hloanalysis.py": 7,
    "test_kv_pool.py": 7,
    "test_planner.py": 35,
    "test_policy.py": 5,
    "test_precision.py": 6,
    "test_tiling_sharding.py": 6,
    "test_scheduling.py": 4,
}
DEFAULT_WEIGHT = 45


def assign(files, num_shards):
    """LPT greedy: heaviest file to the lightest shard; ties broken by
    name order, so the assignment is stable across runs and platforms."""
    loads = [0.0] * num_shards
    shards = [[] for _ in range(num_shards)]
    ranked = sorted(files,
                    key=lambda f: (-WEIGHTS.get(os.path.basename(f),
                                                DEFAULT_WEIGHT), f))
    for f in ranked:
        i = min(range(num_shards), key=lambda j: (loads[j], j))
        loads[i] += WEIGHTS.get(os.path.basename(f), DEFAULT_WEIGHT)
        shards[i].append(f)
    return [sorted(s) for s in shards], loads


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shard", type=int, default=None)
    ap.add_argument("--num-shards", type=int, default=3)
    ap.add_argument("--tests-dir", default=None)
    ap.add_argument("--list", action="store_true",
                    help="print every shard with its modeled load")
    args = ap.parse_args(argv)

    tests_dir = args.tests_dir or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "tests")
    files = sorted(os.path.relpath(f)
                   for f in glob.glob(os.path.join(tests_dir, "test_*.py")))
    if not files:
        print("no test files found", file=sys.stderr)
        return 1
    shards, loads = assign(files, args.num_shards)
    # invariant: a file in exactly one shard — the matrix covers the suite
    flat = [f for s in shards for f in s]
    assert sorted(flat) == files, "shard assignment lost/duplicated files"

    if args.list or args.shard is None:
        for i, (s, w) in enumerate(zip(shards, loads)):
            print(f"shard {i} (~{w:.0f}s): {' '.join(s)}")
        return 0
    if not 0 <= args.shard < args.num_shards:
        print(f"--shard must be in [0, {args.num_shards})", file=sys.stderr)
        return 1
    print(" ".join(shards[args.shard]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
