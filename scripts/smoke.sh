#!/usr/bin/env bash
# Fast pre-merge smoke: the tier-1 suite minus slow markers, then the
# serving benchmark in --dry mode (asserts the continuous engine beats the
# wave baseline on the mixed-length trace).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q -m "not slow"
python -m benchmarks.serve_bench --dry
