#!/usr/bin/env bash
# Fast pre-merge smoke: the tier-1 suite minus slow markers, the kernel
# sweep in --smoke mode (fused vs spill vs XLA at tiny shapes; gates "no
# partial-plane allocation" + the fused traffic win and writes
# experiments/bench/kernels_bench_smoke.json — the committed full-sweep
# artifact is never clobbered), the serving benchmark in --dry
# mode (asserts dense-continuous beats wave, paged == dense
# token-for-token, scheduled-backend == XLA-backend token-for-token with a
# 100% schedule-cache hit rate, paged peak KV below dense, decode gap
# bounded by one chunk, the scheduling-policy gates on the overload
# trace: best_fit pool-utilization and slo_preempt p95-TTFT wins over
# fifo with token-identical output and a clean pool.check() every step,
# and the speculative gates on the repetition trace: ngram + model spec
# rows token-identical to vanilla paged with >= 1.5x fewer decode
# dispatches and 100% verify-shape schedule hits, and the chaos gates on
# the fixed fault schedule: every request terminal, fault-untouched
# output token-identical across the warm restart, recovery overhead
# bounded), then a paged-engine
# smoke: tiny config, 4 requests sharing a prompt prefix — asserts block
# reuse actually happened, plus an ngram speculative run over the same
# engine shape asserting identical tokens in fewer dispatches, plus a
# quantized-serving run (int8 weights + int8 KV with scale sidecars)
# asserting >= 99% greedy agreement at <= 0.5x KV bytes, plus a
# chaos smoke: the same trace under an injected allocation denial and a
# mid-trace crash, asserting token-identical recovery through
# serve_with_restarts (docs/RELIABILITY.md).  CI diffs
# the smoke JSON artifacts against the committed baselines afterwards
# (scripts/bench_gate.py).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q -m "not slow"
python -m benchmarks.kernels_bench --smoke
python -m benchmarks.serve_bench --dry

# telemetry smoke: a profiled serve run exports a Chrome trace + metrics
# snapshot (experiments/obs/, uploaded as CI artifacts), then
# trace_report validates the trace and asserts the drift table covers
# all four hot dispatches (docs/OBSERVABILITY.md).
mkdir -p experiments/obs
python -m repro.launch.serve --arch qwen2-0.5b --scaled-down \
    --requests 6 --max-new 12 --slots 2 --max-len 96 --spec ngram \
    --profile --trace-out experiments/obs/trace_smoke.json \
    --metrics-out experiments/obs/metrics_smoke.json
python scripts/trace_report.py experiments/obs/trace_smoke.json \
    --metrics experiments/obs/metrics_smoke.json --validate \
    --calibration-out experiments/obs/calibration_smoke.json

# planner smoke: fit the workload model's calibration from the trace
# just exported and report modeled-vs-measured TTFT/TPOT drift
# (docs/PLANNER.md).  Report-only here — the speculative accept-length
# estimate is noisy at 6 requests; the gated drift bound lives in
# serve_bench's non-speculative paged_planner row (scripts/bench_gate.py).
python scripts/plan_report.py drift experiments/obs/trace_smoke.json \
    --arch qwen2-0.5b --scaled-down --slots 2 --max-len 96 --spec \
    --calibration experiments/obs/calibration_smoke.json

python - << 'EOF'
import numpy as np, jax
from repro import configs as CONFIGS
from repro.models import network as N
from repro.serving import ContinuousEngine, Request

cfg = CONFIGS.get("qwen2_0_5b").scaled_down()
params = N.init(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prefix = rng.integers(3, cfg.vocab, 32).astype(np.int32)
reqs = [Request(rid=i,
                prompt=np.concatenate(
                    [prefix, rng.integers(3, cfg.vocab, 5 + i
                                          ).astype(np.int32)]),
                max_new_tokens=4, eos=-1) for i in range(4)]
eng = ContinuousEngine(cfg, params, slots=2, max_len=96)
res = eng.run(reqs)
assert sorted(r.rid for r in res) == [0, 1, 2, 3]
assert all(len(r.tokens) == 4 for r in res)
st = eng.pool.stats()
assert st["shared_token_hits"] > 0, st     # prefix blocks were reused
eng.pool.check()
kv = eng.kv_bytes()
print(f"[smoke] paged engine OK: {st['shared_token_hits']} shared-prefix "
      f"token hits, peak KV {kv['peak']}/{kv['allocated']} B, "
      f"{eng.chunk_steps} chunk batches")

# speculative smoke: same trace through ngram drafting — identical greedy
# tokens, fewer decode dispatches, clean pool after every audited step.
base = {r.rid: list(map(int, r.tokens)) for r in res}
sp = ContinuousEngine(cfg, params, slots=2, max_len=96, spec="ngram",
                      spec_k=4, audit=True)
sres = sp.run([Request(rid=r.rid, prompt=r.prompt.copy(),
                       max_new_tokens=r.max_new_tokens, eos=r.eos)
               for r in reqs])
assert {r.rid: list(map(int, r.tokens)) for r in sres} == base
assert sp.steps < eng.steps, (sp.steps, eng.steps)
sp.pool.check()
ss = sp.spec_stats()
print(f"[smoke] spec engine OK: {ss['tokens_emitted']} tokens in "
      f"{ss['verify_steps']} verify dispatches (vanilla {eng.steps}), "
      f"avg accept len {ss['avg_accept_len']:.2f}")

# quantized-serving smoke: the same trace through a quant_serving engine
# (int8 QuantTensor weights via min_size=0 — scaled-down projections are
# below the production floor — plus int8 KV blocks with scale sidecars).
# Greedy output must match the fp engine at >= 99% of positions, the
# pool must allocate <= 0.5x the fp engine's KV bytes, and the audit-
# mode pool check must hold (docs/QUANTIZATION.md).
import dataclasses
from repro.quant import QuantPolicy

cfgq = dataclasses.replace(cfg, quant_serving=True,
                           name=cfg.name + "+int8").validate()
qe = ContinuousEngine(cfgq, params, slots=2, max_len=96, audit=True,
                      quant_policy=QuantPolicy(min_size=0))
qres = qe.run([Request(rid=r.rid, prompt=r.prompt.copy(),
                       max_new_tokens=r.max_new_tokens, eos=r.eos)
               for r in reqs])
qtok = {r.rid: list(map(int, r.tokens)) for r in qres}
match = sum(int(a == b) for rid in base
            for a, b in zip(base[rid], qtok[rid]))
total = sum(len(v) for v in base.values())
assert match / total >= 0.99, (match, total)
ratio = qe.kv_bytes()["allocated"] / kv["allocated"]
assert ratio <= 0.5, ratio
assert qe.pool.stats()["quantized"], qe.pool.stats()
qe.pool.check()
print(f"[smoke] quant engine OK: {match}/{total} greedy tokens match fp, "
      f"KV bytes {ratio:.2f}x fp, pool audit clean")

# chaos smoke: the same trace under an injected allocation denial and a
# mid-trace engine crash — serve_with_restarts must warm-restart into a
# second engine and finish every request ok with IDENTICAL greedy
# tokens, leaving an audit-clean pool (docs/RELIABILITY.md).
from repro.serving import FaultPlane, serve_with_restarts
from repro.serving.resilience import FaultSpec

plane = FaultPlane([FaultSpec("reserve", at=1), FaultSpec("crash", at=8)])
engines = []

def make_engine():
    engines.append(ContinuousEngine(cfg, params, slots=2, max_len=96,
                                    audit=True, faults=plane))
    return engines[-1]

cres = serve_with_restarts(
    make_engine, [Request(rid=r.rid, prompt=r.prompt.copy(),
                          max_new_tokens=r.max_new_tokens, eos=r.eos)
                  for r in reqs], max_steps=2000)
assert {r.status for r in cres} == {"ok"}, [(r.rid, r.status) for r in cres]
assert {r.rid: list(map(int, r.tokens)) for r in cres} == base
assert len(engines) == 2, len(engines)      # the crash really restarted
engines[-1].pool.check()
print(f"[smoke] chaos OK: faults {[f['kind'] for f in plane.fired]}, "
      f"{len(engines)} engines, tokens identical across the warm restart")
EOF
