#!/usr/bin/env python
"""Bench-regression gate: fresh smoke artifacts vs committed trajectory.

CI runs ``scripts/smoke.sh``, which rewrites
``experiments/bench/kernels_bench_smoke.json`` and
``experiments/bench/serve_bench_smoke.json``; this script diffs those
fresh files against the versions committed at HEAD and fails on any
regression beyond a stated tolerance.  Only DETERMINISTIC metrics are
gated (modeled traffic ratios, decode-step counts, block telemetry, the
dispatch-count TTFT proxy) — wall-clock numbers are never compared, CI
hosts are too noisy.

A metric missing from the BASELINE is skipped with a note (first PR
that introduces it has nothing to diff against); a metric missing from
the FRESH output fails (a gated signal silently disappeared).

    git show HEAD:experiments/bench/kernels_bench_smoke.json > /tmp/bk.json
    git show HEAD:experiments/bench/serve_bench_smoke.json  > /tmp/bs.json
    python scripts/bench_gate.py \
        --baseline-kernels /tmp/bk.json \
        --fresh-kernels experiments/bench/kernels_bench_smoke.json \
        --baseline-serve /tmp/bs.json \
        --fresh-serve experiments/bench/serve_bench_smoke.json
"""

from __future__ import annotations

import argparse
import json
import sys

#: (file, dotted path, direction, relative tolerance).  Paths into the
#: serve file address the row list as ``engine=<name>.<key>``.
#: "higher" = higher is better: fresh >= baseline * (1 - tol).
#: "lower"  = lower is better:  fresh <= baseline * (1 + tol).
#: "true"   = boolean gate that must stay true.
CHECKS = [
    ("kernels", "summary.no_spill_gate", "true", 0.0),
    ("kernels", "summary.geomean_traffic_ratio", "higher", 0.02),
    ("kernels", "summary.min_out_traffic_ratio", "higher", 0.02),
    ("serve", "engine=dense.decode_steps", "lower", 0.10),
    ("serve", "engine=paged.decode_steps", "lower", 0.10),
    ("serve", "engine=paged.kv_peak_bytes", "lower", 0.10),
    ("serve", "engine=paged.pool.shared_token_hits", "higher", 0.10),
    ("serve", "engine=policy_best_fit.avg_pool_util", "higher", 0.10),
    ("serve", "engine=policy_slo_preempt.p95_ttft_steps", "lower", 0.15),
    # speculative decoding (rep trace): dispatch counts and acceptance
    # length are deterministic (greedy accept against a fixed trace)
    ("serve", "engine=paged_spec_ngram.decode_steps", "lower", 0.10),
    ("serve", "engine=paged_spec_model.decode_steps", "lower", 0.10),
    ("serve", "engine=paged_spec_ngram.spec.avg_accept_len", "higher", 0.10),
    ("serve", "engine=paged_spec_model.spec.avg_accept_len", "higher", 0.05),
    # quantized serving (paged_quant row): the pool-bytes win and the
    # greedy-agreement floor are computed in-process by serve_bench
    # against its own fp reference (booleans gated); the raw rates are
    # also gated so a drift INSIDE the floor still shows up as a
    # trajectory regression
    ("serve", "engine=paged_quant.pool_bytes_ok", "true", 0.0),
    ("serve", "engine=paged_quant.token_match_ok", "true", 0.0),
    ("serve", "engine=paged_quant.token_match_rate", "higher", 0.01),
    ("serve", "engine=paged_quant.kv_bytes_ratio", "lower", 0.05),
    ("serve", "engine=paged_quant.schedule_hit_rate_run", "higher", 0.0),
    # telemetry: enabled tracing must stay within the serve_bench bound
    # (the row computes the A/B in-process from min-of-N alternating
    # walls; the boolean is what gets gated, never the raw wall numbers)
    ("serve", "engine=paged_telemetry.telemetry_overhead_ok", "true", 0.0),
    # capacity planner (docs/PLANNER.md): the calibrated workload model
    # must keep predicting the smoke trace's TTFT p95 and TPOT inside
    # serve_bench's ±30% drift bound (booleans computed in-process from
    # the profiled run — never raw wall numbers), and the model-driven
    # policy row must keep beating the heuristics it generalizes:
    # slo_preempt's p95 TTFT proxy and best_fit's pool utilization
    ("serve", "engine=paged_planner.planner_drift.ttft_p95_ok", "true", 0.0),
    ("serve", "engine=paged_planner.planner_drift.tpot_ok", "true", 0.0),
    ("serve", "engine=policy_model.p95_ttft_steps", "lower", 0.15),
    ("serve", "engine=policy_model.avg_pool_util", "higher", 0.10),
    # resilience (fixed chaos schedule, docs/RELIABILITY.md): every
    # request terminal, fault-untouched output token-identical, recovery
    # within CHAOS_RECOVERY_BOUND of the fault-free wall — all computed
    # in-process by serve_bench.run_chaos_bench, booleans gated here
    ("serve", "engine=paged_chaos.all_terminal", "true", 0.0),
    ("serve", "engine=paged_chaos.unaffected_token_identical", "true", 0.0),
    ("serve", "engine=paged_chaos.recovery_overhead_ok", "true", 0.0),
]


def lookup(doc, path):
    """Walk ``a.b.c`` with ``engine=<name>`` row selection; KeyError on
    a missing step."""
    cur = doc
    for part in path.split("."):
        if part.startswith("engine="):
            name = part.split("=", 1)[1]
            rows = [r for r in cur if r.get("engine") == name]
            if not rows:
                raise KeyError(f"no row with engine={name}")
            cur = rows[0]
        else:
            if not isinstance(cur, dict) or part not in cur:
                raise KeyError(part)
            cur = cur[part]
    return cur


def run_checks(docs):
    failures, skipped = [], []
    for which, path, direction, tol in CHECKS:
        base_doc, fresh_doc = docs[which]
        try:
            fresh = lookup(fresh_doc, path)
        except KeyError as e:
            failures.append(f"{which}:{path}: missing from FRESH output "
                            f"({e}) — a gated metric disappeared")
            continue
        try:
            base = lookup(base_doc, path)
        except KeyError:
            skipped.append(f"{which}:{path}: not in committed baseline "
                           f"yet, skipping (will be gated next PR)")
            continue
        if direction == "true":
            if not (bool(base) and bool(fresh)):
                failures.append(f"{which}:{path}: expected true, baseline="
                                f"{base} fresh={fresh}")
            continue
        base, fresh = float(base), float(fresh)
        if direction == "higher":
            bound = base * (1.0 - tol)
            ok = fresh >= bound
            rel = "below" if not ok else ">="
        else:
            bound = base * (1.0 + tol)
            ok = fresh <= bound
            rel = "above" if not ok else "<="
        if not ok:
            failures.append(
                f"{which}:{path}: fresh {fresh:g} {rel} tolerance bound "
                f"{bound:g} (baseline {base:g}, tol {tol:.0%}) — "
                f"{'modeled-traffic' if which == 'kernels' else 'serving'} "
                f"regression")
    return failures, skipped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    for name in ("baseline-kernels", "fresh-kernels",
                 "baseline-serve", "fresh-serve"):
        ap.add_argument(f"--{name}", required=True)
    args = ap.parse_args(argv)

    def load(p):
        with open(p) as f:
            return json.load(f)

    docs = {"kernels": (load(args.baseline_kernels),
                        load(args.fresh_kernels)),
            "serve": (load(args.baseline_serve), load(args.fresh_serve))}
    failures, skipped = run_checks(docs)
    for msg in skipped:
        print(f"SKIP: {msg}")
    for msg in failures:
        print(f"FAIL: {msg}")
    if not failures:
        n = len(CHECKS) - len(skipped)
        print(f"bench gate OK: {n} checks within tolerance "
              f"({len(skipped)} skipped)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
